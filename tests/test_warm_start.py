"""Warm-start (incremental) PBQP re-solve correctness.

The serving subsystem re-solves a bucket's PBQP instance starting from a
neighbouring bucket's optimum: the previous assignment's cost on the new
instance seeds branch-and-bound as an achievable upper bound.  These are
the acceptance-criteria tests: across randomized perturbations of node
cost vectors, the warm solve must return exactly the fresh exact-solve
optimum (bound pruning is optimality preserving), including under stale,
invalid or infeasible warm assignments.
"""
import numpy as np
import pytest

from repro.core import pbqp
from repro.core.pbqp import PBQP, Infeasible, brute_force, solve, solve_warm

N_CASES = 60  # acceptance criterion: >= 50 randomized perturbation cases


def _random_instance(rng, n_lo=4, n_hi=7, inf_frac=0.1):
    n = int(rng.integers(n_lo, n_hi + 1))
    pb = PBQP()
    doms = []
    for i in range(n):
        k = int(rng.integers(2, 4))
        doms.append(k)
        pb.add_node(i, rng.uniform(0, 100, size=k))
    for i in range(n):
        for j in range(i + 1, n):
            if rng.random() < 0.55:
                M = rng.choice([0.0, 1.0, 5.0, 25.0], size=(doms[i], doms[j]))
                M = np.where(rng.random(M.shape) < inf_frac, np.inf, M)
                pb.add_edge(i, j, M)
    return pb, doms


def _perturb(pb, doms, rng):
    """Replace a random subset of node cost vectors (the bucket-shift)."""
    nodes = pb.nodes
    subset = rng.choice(len(nodes), size=max(1, len(nodes) // 2),
                        replace=False)
    for i in subset:
        pb.set_node_cost(nodes[i], rng.uniform(0, 100, size=doms[i]))


class TestWarmMatchesFresh:
    def test_randomized_perturbations(self):
        rng = np.random.default_rng(7)
        checked = 0
        while checked < N_CASES:
            pb, doms = _random_instance(rng)
            try:
                prev = solve(pb, exact=True)
            except Infeasible:
                continue  # nothing to warm-start from
            _perturb(pb, doms, rng)
            try:
                fresh = solve(pb, exact=True)
            except Infeasible:
                with pytest.raises(Infeasible):
                    solve_warm(pb, prev.assignment, exact=True)
                checked += 1
                continue
            warm = solve_warm(pb, prev.assignment, exact=True)
            assert warm.optimal and fresh.optimal
            assert warm.cost == pytest.approx(fresh.cost, abs=1e-9)
            assert pb.evaluate(warm.assignment) == pytest.approx(warm.cost)
            checked += 1
        assert checked >= N_CASES

    def test_warm_matches_brute_force_small(self):
        rng = np.random.default_rng(11)
        checked = 0
        while checked < 25:
            pb, doms = _random_instance(rng, n_lo=3, n_hi=5)
            try:
                prev = solve(pb, exact=True)
            except Infeasible:
                continue
            _perturb(pb, doms, rng)
            try:
                bf = brute_force(pb)
            except Infeasible:
                continue
            warm = solve_warm(pb, prev.assignment, exact=True)
            assert warm.cost == pytest.approx(bf.cost, abs=1e-9)
            checked += 1


class TestWarmStartRobustness:
    def _dense(self, rng, n=5, k=3):
        """Dense instance: guaranteed to exercise branch-and-bound."""
        pb = PBQP()
        for i in range(n):
            pb.add_node(i, rng.uniform(1, 100, size=k))
        for i in range(n):
            for j in range(i + 1, n):
                pb.add_edge(i, j, rng.uniform(0, 50, size=(k, k)))
        return pb

    def test_warm_bound_recorded(self):
        rng = np.random.default_rng(0)
        pb = self._dense(rng)
        prev = solve(pb, exact=True)
        warm = solve_warm(pb, prev.assignment, exact=True)
        assert warm.stats["WARM"] == 1
        assert warm.cost == pytest.approx(prev.cost)

    def test_identity_warm_start_prunes(self):
        """Re-solving with its own optimum as bound must not search more
        branch-and-bound nodes than the cold solve."""
        rng = np.random.default_rng(3)
        pb = self._dense(rng, n=6, k=3)
        cold = solve(pb, exact=True)
        warm = solve_warm(pb, cold.assignment, exact=True)
        assert warm.cost == pytest.approx(cold.cost)
        assert warm.stats["BB"] <= cold.stats["BB"]

    def test_invalid_warm_assignment_degrades_to_cold(self):
        rng = np.random.default_rng(1)
        pb = self._dense(rng)
        cold = solve(pb, exact=True)
        for bad in (None, {}, {0: 0}, {i: 99 for i in pb.nodes}):
            warm = solve_warm(pb, bad, exact=True)
            assert warm.stats["WARM"] == 0
            assert warm.cost == pytest.approx(cold.cost)

    def test_infeasible_warm_cost_degrades_to_cold(self):
        pb = PBQP()
        pb.add_node("a", [0.0, 5.0])
        pb.add_node("b", [0.0, 5.0])
        pb.add_edge("a", "b", np.array([[np.inf, 0.0], [0.0, 0.0]]))
        warm = solve_warm(pb, {"a": 0, "b": 0})  # inf-cost assignment
        assert warm.stats["WARM"] == 0
        assert warm.cost == pytest.approx(5.0)

    def test_set_node_cost_validates(self):
        pb = PBQP()
        pb.add_node("a", [1.0, 2.0])
        with pytest.raises(KeyError):
            pb.set_node_cost("zzz", [1.0, 2.0])
        with pytest.raises(ValueError):
            pb.set_node_cost("a", [1.0, 2.0, 3.0])

    def test_copy_is_independent(self):
        pb = PBQP()
        pb.add_node("a", [1.0, 2.0])
        pb.add_node("b", [3.0, 4.0])
        pb.add_edge("a", "b", np.eye(2))
        cp = pb.copy()
        cp.set_node_cost("a", [100.0, 200.0])
        assert solve(pb).cost != solve(cp).cost


class TestSelectionWarmStart:
    def test_neighbouring_bucket_same_optimum(self):
        from repro.core.costs import AnalyticCostModel
        from repro.core.selection import select_pbqp
        from repro.serving import conv_tower

        cm = AnalyticCostModel()
        net_a = conv_tower((4, 32, 32), depth=2, width=8)
        net_b = conv_tower((4, 64, 64), depth=2, width=8)
        prev = select_pbqp(net_a, cm, exact=True)
        fresh = select_pbqp(net_b, cm, exact=True)
        warm = select_pbqp(net_b, cm, exact=True, warm_start=prev)
        assert warm.optimal and fresh.optimal
        assert warm.predicted_cost == pytest.approx(fresh.predicted_cost)
        assert warm.solver_stats.get("WARM") == 1

    def test_unified_choice_space_same_optimum(self):
        """Warm starts on the placement-extended (unified choice-space)
        graph stay cost-identical to fresh exact solves — both across
        neighbouring buckets and across the mesh/no-mesh axis (a
        meshless plan seeding a mesh solve degrades to a
        placement-agnostic match, never to a wrong optimum)."""
        from repro.core.costs import AnalyticCostModel
        from repro.core.selection import select_pbqp
        from repro.serving import conv_tower

        cm = AnalyticCostModel()
        axes = {"data": 8}
        net_a = conv_tower((4, 32, 32), depth=2, width=8).with_batch(8)
        net_b = conv_tower((4, 64, 64), depth=2, width=8).with_batch(8)
        prev = select_pbqp(net_a, cm, exact=True, mesh_axes=axes)
        assert any(c.placement == "dp" for c in prev.choices.values())
        fresh = select_pbqp(net_b, cm, exact=True, mesh_axes=axes)
        warm = select_pbqp(net_b, cm, exact=True, mesh_axes=axes,
                           warm_start=prev)
        assert warm.optimal and fresh.optimal
        assert warm.predicted_cost == pytest.approx(fresh.predicted_cost)
        assert warm.solver_stats.get("WARM") == 1
        assert {n: (c.primitive.name if c.primitive else None,
                    c.placement) for n, c in warm.choices.items()} == \
               {n: (c.primitive.name if c.primitive else None,
                    c.placement) for n, c in fresh.choices.items()}
        # cross-axis: a plan solved WITHOUT a mesh warm-starts the mesh
        # solve of the same bucket (placement match degrades gracefully)
        prev0 = select_pbqp(net_b, cm, exact=True)
        warm2 = select_pbqp(net_b, cm, exact=True, mesh_axes=axes,
                            warm_start=prev0)
        assert warm2.predicted_cost == pytest.approx(fresh.predicted_cost)
        assert warm2.optimal

    def test_enlarged_placement_space_same_optimum(self):
        """A {dp, rep}-era optimum (solved on a data-only mesh) seeds
        the solve over the enlarged {dp, tp, pp, rep} domain and still
        reaches the identical optimum — warm starts are pure
        acceleration, never a constraint, even when the domain the seed
        was solved over is a strict subset of the new one."""
        from repro.core.costs import AnalyticCostModel
        from repro.core.selection import Placement, select_pbqp
        from repro.serving.towers import bottleneck_tower

        cm = AnalyticCostModel()
        net = bottleneck_tower((4, 16, 16)).with_batch(8)
        # seed: the old two-kind world (dp over 8 flattened devices)
        prev = select_pbqp(net, cm, exact=True, mesh_axes={"data": 8})
        assert {Placement.parse(c.placement).kind
                for c in prev.choices.values()} <= {"dp", "rep"}
        axes = {"data": 2, "model": 4}
        fresh = select_pbqp(net, cm, exact=True, mesh_axes=axes)
        warm = select_pbqp(net, cm, exact=True, mesh_axes=axes,
                           warm_start=prev)
        assert warm.optimal and fresh.optimal
        assert warm.predicted_cost == pytest.approx(fresh.predicted_cost)
        assert warm.solver_stats.get("WARM") == 1
        # the enlarged space genuinely changes the answer: the warm
        # solve must follow it to tp, not stick with the dp seed
        kinds = {Placement.parse(c.placement).kind
                 for c in warm.choices.values()}
        assert "tp" in kinds, kinds
        assert {n: (c.primitive.name if c.primitive else None,
                    str(c.placement))
                for n, c in warm.choices.items()} == \
               {n: (c.primitive.name if c.primitive else None,
                    str(c.placement))
                for n, c in fresh.choices.items()}

    def test_pipeline_space_warm_start(self):
        """Same property on the stage axis: a meshless seed warm-starts
        a pipeline solve to the fresh optimum."""
        from repro.core.costs import AnalyticCostModel
        from repro.core.selection import Placement, select_pbqp
        from repro.serving.towers import uniform_stack

        cm = AnalyticCostModel()
        net = uniform_stack((8, 8, 8), depth=6).with_batch(8)
        prev = select_pbqp(net, cm, exact=True)
        fresh = select_pbqp(net, cm, exact=True, mesh_axes={"stage": 4})
        warm = select_pbqp(net, cm, exact=True, mesh_axes={"stage": 4},
                           warm_start=prev)
        assert warm.optimal and fresh.optimal
        assert warm.predicted_cost == pytest.approx(fresh.predicted_cost)
        assert all(Placement.parse(c.placement).kind == "pp"
                   for c in warm.choices.values())
