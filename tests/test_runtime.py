"""Fault-tolerance, checkpointing, data and serving tests."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.checkpoint import Checkpointer
from repro.configs import SHAPES, get_config
from repro.configs.base import ShapeConfig
from repro.data.pipeline import make_batch
from repro.models import ModelRuntime, ShardingPlan, init_params
from repro.optim import adamw, warmup_cosine
from repro.runtime import (
    Request, ServeLoop, StragglerMonitor, TrainLoopConfig, train,
)

CFG = get_config("tinyllama-1.1b").scaled_down(n_layers=2, d_model=64,
                                               d_ff=128, vocab=256)
SHAPE = ShapeConfig("tiny_train", seq_len=32, global_batch=4, kind="train")
OPT = adamw(warmup_cosine(1e-3, 10, 200))


class TestCheckpointer:
    def test_roundtrip(self, tmp_path):
        ck = Checkpointer(tmp_path, keep=2)
        tree = {"a": jnp.arange(5, dtype=jnp.float32),
                "b": {"c": jnp.ones((2, 3), jnp.bfloat16)}}
        ck.save(7, tree, extra={"loss": 1.5})
        step, restored, extra = ck.restore(tree)
        assert step == 7 and extra["loss"] == 1.5
        np.testing.assert_array_equal(restored["a"], tree["a"])
        assert restored["b"]["c"].dtype == jnp.bfloat16

    def test_rotation_keeps_latest(self, tmp_path):
        ck = Checkpointer(tmp_path, keep=2)
        t = {"x": jnp.zeros(3)}
        for s in [1, 2, 3, 4]:
            ck.save(s, t)
        assert ck.steps() == [3, 4]

    def test_atomic_no_partial(self, tmp_path):
        ck = Checkpointer(tmp_path, keep=3)
        t = {"x": jnp.arange(4.0)}
        ck.save(1, t)
        # a stale tmp dir from a crashed writer must not break restore
        (tmp_path / "step_2.tmp").mkdir()
        assert ck.latest_step() == 1
        step, _, _ = ck.restore(t)
        assert step == 1


class TestData:
    def test_deterministic_per_step(self):
        b1 = make_batch(CFG, SHAPE, 5, seed=1)
        b2 = make_batch(CFG, SHAPE, 5, seed=1)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        b3 = make_batch(CFG, SHAPE, 6, seed=1)
        assert not np.array_equal(b1["tokens"], b3["tokens"])

    def test_labels_shifted(self):
        b = make_batch(CFG, SHAPE, 0)
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


class TestTrainLoop:
    def test_loss_decreases(self, tmp_path):
        metrics = []
        train(CFG, SHAPE, OPT,
              loop=TrainLoopConfig(total_steps=30, ckpt_every=10,
                                   ckpt_dir=str(tmp_path), log_every=0),
              metrics_out=metrics)
        first = np.mean([m["loss"] for m in metrics[:5]])
        last = np.mean([m["loss"] for m in metrics[-5:]])
        assert last < first, f"no learning: {first} -> {last}"

    def test_restart_resumes_and_matches(self, tmp_path):
        """Train 30 straight vs 15 + restart + 15: identical losses
        (deterministic pipeline + checkpointed state)."""
        m_full = []
        train(CFG, SHAPE, OPT,
              loop=TrainLoopConfig(total_steps=30, ckpt_every=15,
                                   ckpt_dir=str(tmp_path / "a"),
                                   log_every=0),
              metrics_out=m_full)
        m1, m2 = [], []
        train(CFG, SHAPE, OPT,
              loop=TrainLoopConfig(total_steps=15, ckpt_every=15,
                                   ckpt_dir=str(tmp_path / "b"),
                                   log_every=0),
              metrics_out=m1)
        train(CFG, SHAPE, OPT,
              loop=TrainLoopConfig(total_steps=30, ckpt_every=15,
                                   ckpt_dir=str(tmp_path / "b"),
                                   log_every=0),
              metrics_out=m2)
        full_by_step = {m["step"]: m["loss"] for m in m_full}
        for m in m2:
            assert abs(m["loss"] - full_by_step[m["step"]]) < 1e-4, \
                f"divergence at step {m['step']} after restart"

    def test_fault_injection_recovers(self, tmp_path):
        """Inject failures at steps 12 and 18; loop must restore from
        checkpoints and still finish all 25 steps."""
        fails = {12, 18}

        def fault(step):
            if step in fails:
                fails.discard(step)
                raise RuntimeError(f"injected node failure @ {step}")

        metrics = []
        st = train(CFG, SHAPE, OPT,
                   loop=TrainLoopConfig(total_steps=25, ckpt_every=5,
                                        ckpt_dir=str(tmp_path),
                                        log_every=0),
                   fault_hook=fault, metrics_out=metrics)
        assert st.step == 25
        assert not fails  # both faults actually fired
        assert max(m["step"] for m in metrics) == 24

    def test_persistent_failure_aborts(self, tmp_path):
        def always_fail(step):
            raise RuntimeError("dead node")

        with pytest.raises(RuntimeError, match="aborting"):
            train(CFG, SHAPE, OPT,
                  loop=TrainLoopConfig(total_steps=5, ckpt_every=2,
                                       ckpt_dir=str(tmp_path),
                                       max_retries=2, log_every=0),
                  fault_hook=always_fail)


class TestStragglerMonitor:
    def test_detects_slow_steps(self):
        mon = StragglerMonitor(factor=3.0)
        flags = [mon.observe(i, 0.1) for i in range(10)]
        assert not any(flags)
        assert mon.observe(10, 1.0)          # 10x slower
        assert len(mon.stragglers) == 1
        # EWMA not poisoned: a normal step right after is not flagged
        assert not mon.observe(11, 0.1)


class TestServeLoop:
    def test_continuous_batching(self):
        params = init_params(CFG, jax.random.key(0), jnp.float32)
        loop = ServeLoop(CFG, params, max_batch=2, max_seq=48)
        rng = np.random.default_rng(0)
        reqs = [Request(rid=i,
                        prompt=rng.integers(0, CFG.vocab, size=5 + i)
                        .astype(np.int32),
                        max_new_tokens=4)
                for i in range(5)]  # 5 requests > 2 slots
        done = loop.run(reqs, max_ticks=200)
        assert all(r.done for r in done)
        assert all(len(r.tokens) == 4 for r in done)

    def test_serve_matches_offline_decode(self):
        """Continuous-batching output == straight prefill+argmax decode."""
        from repro.models import decode_step, prefill
        params = init_params(CFG, jax.random.key(0), jnp.float32)
        rng = np.random.default_rng(1)
        prompt = rng.integers(0, CFG.vocab, size=7).astype(np.int32)

        plan = ShardingPlan(mesh=None)
        logits, cache = prefill(CFG, params,
                                {"tokens": jnp.asarray(prompt[None])},
                                plan, max_seq=32)
        want = [int(jnp.argmax(logits[0, -1]))]
        pos = len(prompt)
        for _ in range(3):
            lg, cache = decode_step(CFG, params, cache,
                                    jnp.asarray([[want[-1]]]), pos, plan)
            want.append(int(jnp.argmax(lg[0, 0])))
            pos += 1

        loop = ServeLoop(CFG, params, max_batch=2, max_seq=32)
        req = Request(rid=0, prompt=prompt, max_new_tokens=4)
        loop.run([req], max_ticks=50)
        assert req.tokens == want
