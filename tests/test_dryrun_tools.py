"""Unit tests for dry-run analysis helpers (HLO collective parsing,
unroll-differencing reconstruction, roofline term derivation)."""
import numpy as np
import pytest

from benchmarks.roofline import roofline_terms
from repro.launch.dryrun import _combine_unrolls, _type_bytes, \
    parse_collectives

HLO = """
HloModule jit_step

fused_computation {
  ...
}

ENTRY main {
  %p0 = bf16[8,1024]{1,0} parameter(0)
  %p1 = f32[16,16]{1,0} parameter(1)
  %ag = bf16[8,2048]{1,0} all-gather(%p0), channel_id=1, dimensions={1}
  %ar = f32[16,16]{1,0} all-reduce(%p1), channel_id=2, to_apply=%add
  %rs = bf16[4,1024]{1,0} reduce-scatter(%p0), channel_id=3
  %a2a = bf16[8,1024]{1,0} all-to-all(%p0), channel_id=4
  %cp.1 = bf16[8,1024]{1,0} collective-permute(%p0), channel_id=5
  %ars = f32[16,16]{1,0} all-reduce-start(%p1), channel_id=6
  ROOT %t = (bf16[8,2048]{1,0}) tuple(%ag)
}
"""


class TestTypeBytes:
    def test_simple(self):
        assert _type_bytes("bf16[8,1024]{1,0}") == 8 * 1024 * 2
        assert _type_bytes("f32[16,16]{1,0}") == 16 * 16 * 4
        assert _type_bytes("pred[4]") == 4

    def test_tuple(self):
        assert _type_bytes("(bf16[2,2]{1,0}, f32[3]{0})") == 8 + 12

    def test_scalar(self):
        assert _type_bytes("f32[]") == 4


class TestParseCollectives:
    def test_counts_and_bytes(self):
        out = parse_collectives(HLO)
        p0 = 8 * 1024 * 2
        p1 = 16 * 16 * 4
        assert out["all-gather"] == {"count": 1, "bytes": p0}
        # all-reduce + all-reduce-start both count
        assert out["all-reduce"]["count"] == 2
        assert out["all-reduce"]["bytes"] == 2 * p1
        assert out["reduce-scatter"]["bytes"] == p0
        assert out["all-to-all"]["bytes"] == p0
        assert out["collective-permute"]["count"] == 1

    def test_no_false_positives(self):
        out = parse_collectives(
            "%x = f32[4]{0} add(%a, %b)\n%y = f32[4]{0} copy(%x)")
        assert all(v["count"] == 0 for v in out.values())


class TestUnrollDiff:
    def test_reconstruction(self):
        def rec(flops, bytes_, coll):
            return {
                "n_super": 10,
                "flops_per_device": flops,
                "bytes_per_device": bytes_,
                "collectives": {"all-reduce": coll,
                                "all-gather": {"count": 0, "bytes": 0},
                                "reduce-scatter": {"count": 0, "bytes": 0},
                                "all-to-all": {"count": 0, "bytes": 0},
                                "collective-permute": {"count": 0,
                                                       "bytes": 0}},
                "collective_bytes_per_device": coll["bytes"],
            }

        # outside=100, body=50 => u1: 150, u2: 200
        r1 = rec(150.0, 1500.0, {"count": 3, "bytes": 300})
        r2 = rec(200.0, 2000.0, {"count": 5, "bytes": 500})
        out = _combine_unrolls(r1, r2)
        assert out["flops_total"] == 100 + 10 * 50
        assert out["bytes_total"] == 1000 + 10 * 500
        assert out["collectives_total"]["all-reduce"]["bytes"] == \
            100 + 10 * 200
        assert out["collective_bytes_total"] == 2100

    def test_clamping_on_fusion_noise(self):
        """u2 < u1 (fusion noise) must not produce negative totals."""
        r1 = {"n_super": 4, "flops_per_device": 100.0,
              "bytes_per_device": 100.0,
              "collectives": {c: {"count": 0, "bytes": 0} for c in
                              ("all-reduce", "all-gather",
                               "reduce-scatter", "all-to-all",
                               "collective-permute")},
              "collective_bytes_per_device": 0}
        r2 = dict(r1, flops_per_device=90.0)
        out = _combine_unrolls(r1, r2)
        assert out["flops_total"] >= 0


class TestRooflineTerms:
    def test_dominant_term(self):
        rec = {
            "arch": "x", "shape": "train_4k", "mesh": "16x16",
            "n_devices": 256,
            "flops_total": 197e12,        # exactly 1 s of compute
            "bytes_total": 819e9 * 0.5,   # 0.5 s of memory
            "collective_bytes_total": 50e9 * 2.0,  # 2 s of collectives
            "model_flops": 197e12 * 256 * 0.5,     # 0.5 s ideal
        }
        t = roofline_terms(rec)
        assert t["bottleneck"] == "collective"
        assert t["compute_s"] == pytest.approx(1.0)
        assert t["memory_s"] == pytest.approx(0.5)
        assert t["collective_s"] == pytest.approx(2.0)
        assert t["roofline_fraction"] == pytest.approx(0.25)
