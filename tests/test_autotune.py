"""Autotune subsystem: spaces, pruning, catalog, registry extension.

Covers the PR-level invariants:

* every declared parameter space enumerates only valid configurations
  and generated variants compute the same convolution as the reference
  oracle (interpret mode);
* the registry extension mechanism is cached, invalidates correctly,
  rejects duplicate names, and rotates every ``CostModel.version()``;
* dominance pruning is sound (a pruned variant is never the per-bucket
  winner anywhere — property-tested) and order-free (stable under
  permutation of the measurement/candidate order);
* the catalog round-trips through JSON, installs/uninstalls, and
  refuses stale parameter spaces;
* the tuner is resumable and budget-capped, the CLI dry-runs, and
  anytime PBQP honours a solve deadline on the widened registry.
"""
import json
import pathlib
import time

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal install: property tests skip, units run
    from _hypothesis_fallback import given, settings, st

from repro.autotune import (
    Candidate, VariantCatalog, generate_variants, kernel_spaces,
    plan_only, prune_dominated, spaces, tune, variant_name,
)
from repro.calibrate.sweep import scenario_grid, scenarios_from_net
from repro.core.costs import AnalyticCostModel, TPU_V5E_SPEC
from repro.core.layouts import LAYOUT_BY_NAME
from repro.core.primitives import (
    clear_extensions, extension_token, register_extension, registry,
    unregister_extension,
)
from repro.core.scenario import Scenario, ref_conv
from repro.core.selection import select_pbqp
from repro.serving.towers import conv_tower, uniform_stack

pytestmark = pytest.mark.usefixtures("clean_registry")


@pytest.fixture
def clean_registry():
    clear_extensions()
    yield
    clear_extensions()


TPU_COST = lambda: AnalyticCostModel(TPU_V5E_SPEC, include_tpu_only=True)

SCN_K3 = Scenario(c=8, h=12, w=12, stride=1, k=3, m=8)
SCN_K1 = Scenario(c=8, h=10, w=10, stride=1, k=1, m=8, pad=0)


# ----------------------------------------------------------------------
# parameter spaces
# ----------------------------------------------------------------------
class TestSpaces:
    def test_all_kernel_packages_declare_a_space(self):
        sp = spaces()
        assert set(sp) == {"matmul", "conv_direct", "conv_im2col",
                          "winograd_gemm", "flash_attention",
                          "layout_transform"}
        assert sum(s.registers for s in sp.values()) == 4
        assert len(kernel_spaces(None)) == 2

    def test_configs_are_valid_and_named_uniquely(self):
        for s in spaces().values():
            cfgs = s.configs()
            assert cfgs, s.kernel
            names = {s.make_primitive(c).name for c in cfgs} \
                if s.registers else \
                {variant_name(s.kernel, c, s.axis_order) for c in cfgs}
            assert len(names) == len(cfgs), s.kernel
            for c in cfgs:
                assert s.valid(c), (s.kernel, c)
                assert set(c) == set(s.axis_order)

    def test_generated_variants_carry_params_and_unique_names(self):
        variants = generate_variants()
        assert len(variants) > 100
        assert len({p.name for p in variants}) == len(variants)
        base_names = {p.name for p in registry()}
        for p in variants:
            assert p.params and p.family == "pallas"
            assert "@" in p.name and p.name not in base_names

    @pytest.mark.parametrize("kernel,scn", [
        ("conv_im2col", SCN_K3), ("conv_direct", SCN_K3),
        ("winograd_gemm", SCN_K3), ("matmul", SCN_K1),
    ])
    def test_variant_matches_reference_conv(self, kernel, scn):
        """Smallest config of each registering space, interpret mode."""
        space = spaces()[kernel]
        prim = space.make_primitive(space.configs()[0])
        assert prim.supports(scn), prim.name
        rng = np.random.default_rng(0)
        x = rng.normal(size=scn.in_shape_chw).astype(np.float32)
        w = (rng.normal(size=scn.weight_shape) * 0.1).astype(np.float32)
        b = rng.normal(size=(scn.m,)).astype(np.float32)
        want = ref_conv(x, w, b, scn.stride, scn.pad)
        packed = prim.prepare(scn, w, b)
        xin = LAYOUT_BY_NAME[prim.l_in].to_memory(x)
        y = np.asarray(prim.make(scn)(xin, packed))
        got = LAYOUT_BY_NAME[prim.l_out].from_memory(y)
        np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2,
                                   err_msg=prim.name)


# ----------------------------------------------------------------------
# registry extension
# ----------------------------------------------------------------------
class TestRegistryExtension:
    def test_register_unregister_roundtrip(self):
        n0 = len(registry())
        space = spaces()["conv_im2col"]
        prim = space.make_primitive(space.configs()[0])
        register_extension("t", (prim,), token="abc")
        assert len(registry()) == n0 + 1
        assert extension_token() == "t:abc"
        assert unregister_extension("t")
        assert len(registry()) == n0
        assert extension_token() == ""
        assert not unregister_extension("t")

    def test_duplicate_names_rejected(self):
        base = registry()[0]
        with pytest.raises(ValueError, match="duplicate"):
            register_extension("dup", (base,))
        space = spaces()["conv_im2col"]
        prim = space.make_primitive(space.configs()[0])
        register_extension("a", (prim,))
        with pytest.raises(ValueError, match="duplicate"):
            register_extension("b", (prim,))

    def test_cost_model_version_rotates_with_extensions(self):
        cm = TPU_COST()
        v0 = cm.version()
        space = spaces()["conv_im2col"]
        prim = space.make_primitive(space.configs()[0])
        register_extension("t", (prim,), token="abc")
        v1 = cm.version()
        assert v1 != v0
        register_extension("t2", (space.make_primitive(
            space.configs()[1]),), token="xyz")
        assert cm.version() not in (v0, v1)
        clear_extensions()
        assert cm.version() == v0


# ----------------------------------------------------------------------
# dominance pruning
# ----------------------------------------------------------------------
def _cand(name, costs, prunable=True, group="g"):
    return Candidate(name=name, prunable=prunable,
                     group=(group, tuple(sorted(costs))),
                     costs=tuple(sorted(costs.items())))


def _group_of(cands):
    by = {}
    for c in cands:
        by.setdefault(c.group, []).append(c)
    return by


def _check_sound(cands, survivors, pruned):
    """Every pruned candidate is weakly covered by a survivor in its
    group on every bucket — so it can never be the per-bucket winner."""
    surv = set(survivors)
    by_group = _group_of(cands)
    for group in by_group.values():
        live = [c for c in group if c.name in surv]
        for v in group:
            if v.name in surv:
                continue
            vc = v.cost_map()
            assert any(
                set(vc) <= set(u.cost_map())
                and all(u.cost_map()[b] <= vc[b] for b in vc)
                for u in live), f"{v.name} pruned without cover"


class TestPruning:
    def test_dominated_variant_pruned_with_dominator_recorded(self):
        a = _cand("a", {"b0": 1.0, "b1": 1.0})
        b = _cand("b", {"b0": 2.0, "b1": 1.0})
        survivors, pruned = prune_dominated([a, b])
        assert survivors == ["a"] and pruned == {"b": "a"}

    def test_pareto_incomparable_both_survive(self):
        a = _cand("a", {"b0": 1.0, "b1": 3.0})
        b = _cand("b", {"b0": 3.0, "b1": 1.0})
        survivors, pruned = prune_dominated([a, b])
        assert survivors == ["a", "b"] and not pruned

    def test_handwritten_never_pruned_and_wins_ties(self):
        base = _cand("zz_base", {"b0": 1.0}, prunable=False)
        tied = _cand("aa_variant", {"b0": 1.0})
        worse = _cand("mm_variant", {"b0": 2.0})
        survivors, pruned = prune_dominated([base, tied, worse])
        assert survivors == ["zz_base"]
        assert pruned["aa_variant"] == "zz_base"
        # mm's recorded dominator may itself be pruned; the chain must
        # still bottom out in a survivor (transitivity)
        assert set(pruned) == {"aa_variant", "mm_variant"}
        _check_sound([base, tied, worse], survivors, pruned)

    def test_different_groups_never_compared(self):
        a = _cand("a", {"b0": 1.0}, group="g1")
        b = _cand("b", {"b0": 9.0}, group="g2")
        survivors, _ = prune_dominated([a, b])
        assert survivors == ["a", "b"]

    def test_unmeasured_candidate_not_used_as_dominator(self):
        empty = _cand("empty", {})
        a = _cand("a", {"b0": 5.0})
        survivors, pruned = prune_dominated([empty, a])
        assert "a" in survivors and "a" not in pruned

    # -- properties (hypothesis + seeded smoke loop) -------------------
    @staticmethod
    def _random_cands(rng_draw):
        """rng_draw(n) -> int in [0, n); shared shape for both drivers."""
        buckets = [f"b{i}" for i in range(1 + rng_draw(3))]
        support = tuple(buckets[:1 + rng_draw(len(buckets))])
        cands = []
        n = 2 + rng_draw(5)
        costs_alphabet = (1.0, 2.0, 4.0, 8.0)
        for i in range(n):
            costs = {b: costs_alphabet[rng_draw(4)] for b in support}
            cands.append(_cand(f"c{i}", costs,
                               prunable=bool(rng_draw(4)),
                               group="g"))
        return cands

    @given(st.data())
    @settings(max_examples=150, deadline=None)
    def test_property_pruned_never_per_bucket_winner(self, data):
        cands = self._random_cands(
            lambda n: data.draw(st.integers(0, n - 1)))
        survivors, pruned = prune_dominated(cands)
        assert set(survivors) | set(pruned) == {c.name for c in cands}
        _check_sound(cands, survivors, pruned)

    @given(st.data())
    @settings(max_examples=150, deadline=None)
    def test_property_stable_under_permutation(self, data):
        cands = self._random_cands(
            lambda n: data.draw(st.integers(0, n - 1)))
        survivors, pruned = prune_dominated(cands)
        perm = data.draw(st.permutations(cands))
        survivors2, pruned2 = prune_dominated(perm)
        assert survivors == survivors2
        assert set(pruned) == set(pruned2)

    def test_smoke_properties_seeded(self):
        rng = np.random.default_rng(7)
        for _ in range(200):
            cands = self._random_cands(lambda n: int(rng.integers(n)))
            survivors, pruned = prune_dominated(cands)
            _check_sound(cands, survivors, pruned)
            order = rng.permutation(len(cands))
            s2, p2 = prune_dominated([cands[i] for i in order])
            assert survivors == s2 and set(pruned) == set(p2)

    def test_pruning_never_changes_the_pbqp_optimum(self):
        """End to end: solving over survivors-only equals solving over
        the full candidate pool — the pruned variants were never
        needed (the tune sweep covers every bucket the net solves)."""
        net = uniform_stack((256, 16, 16), depth=2, k=1)
        scns = scenarios_from_net(net, batches=(1,))
        cost = TPU_COST()
        res = tune(scns, kernels=("matmul",), measure_mode="analytic")
        surv = res.catalog.build_primitives()
        assert res.pruned > 0
        all_variants = generate_variants(kernels=("matmul",))
        register_extension("all", tuple(all_variants))
        full = select_pbqp(net, cost)
        clear_extensions()
        register_extension("surv", tuple(surv))
        lean = select_pbqp(net, cost)
        assert lean.predicted_cost == pytest.approx(
            full.predicted_cost, rel=1e-9)


# ----------------------------------------------------------------------
# catalog
# ----------------------------------------------------------------------
def _tiny_tune(**kw):
    return tune(scenario_grid("tiny"), measure_mode="analytic", **kw)


class TestCatalog:
    def test_roundtrip_and_install(self, tmp_path):
        res = _tiny_tune()
        cat = res.catalog
        assert res.generated == len(cat.variants) > 0
        path = tmp_path / "cat.json"
        cat.save(path)
        loaded = VariantCatalog.load(path)
        assert loaded.content_hash() == cat.content_hash()
        assert loaded.survivors() == cat.survivors()
        n0 = len(registry())
        n = loaded.install()
        assert n == len(cat.survivors())
        assert len(registry()) == n0 + n
        assert cat.content_hash() in extension_token()
        assert VariantCatalog.uninstall()
        assert len(registry()) == n0

    def test_schema_mismatch_rejected(self, tmp_path):
        res = _tiny_tune()
        payload = res.catalog.to_payload()
        payload["schema"] = 999
        with pytest.raises(ValueError, match="schema"):
            VariantCatalog.from_payload(payload)

    def test_stale_parameter_space_rejected(self):
        res = _tiny_tune()
        cat = res.catalog
        name = cat.survivors()[0]
        entry = cat.variants[name]
        key = next(iter(entry["params"]))
        entry["params"] = dict(entry["params"], **{key: 7777})
        with pytest.raises(ValueError, match="re-run the tuner"):
            cat.build_primitives()

    def test_kernel_only_winners_recorded(self):
        res = _tiny_tune()
        keys = list(res.catalog.kernels)
        assert any(k.startswith("flash_attention::") for k in keys)
        assert any(k.startswith("layout_transform::") for k in keys)
        for e in res.catalog.kernels.values():
            assert e["seconds"] > 0 and e["params"]


# ----------------------------------------------------------------------
# tuner + CLI
# ----------------------------------------------------------------------
class TestTuner:
    def test_budget_caps_and_resumes(self, tmp_path):
        prof_path = tmp_path / "p.json"
        res = _tiny_tune(budget=25, profile_path=prof_path)
        assert res.sweep["measured"] == 25
        assert res.sweep["remaining"] > 0
        res2 = _tiny_tune(profile=res.profile, profile_path=prof_path)
        assert res2.sweep["skipped"] == 25
        assert res2.sweep["remaining"] == 0
        assert res2.surviving >= 1

    def test_plan_only_measures_nothing(self):
        variants, items, index = plan_only(scenario_grid("small"))
        assert len(items) == len(index) > 0 and len(variants) > 0
        prim_keys = [k for k, e in index.items() if e[0] == "prim"]
        assert all(k.startswith("prim::") for k in prim_keys)

    def test_cli_dry_run(self, capsys):
        from repro.launch.tune import main
        assert main(["--catalog", "/nonexistent/never-written.json",
                     "--grid", "tiny", "--dry-run"]) == 0
        out = capsys.readouterr().out
        assert "dry run: nothing measured, nothing written" in out
        assert not pathlib.Path("/nonexistent").exists()

    def test_cli_tiny_run_writes_catalog(self, tmp_path, capsys):
        from repro.launch.tune import main
        cat = tmp_path / "cat.json"
        rc = main(["--catalog", str(cat), "--grid", "tiny",
                   "--kernels", "conv_im2col", "--max-per-kernel", "4",
                   "--measure", "analytic"])
        assert rc == 0
        assert cat.exists() and cat.with_suffix(".profile.json").exists()
        loaded = VariantCatalog.load(cat)
        assert json.loads(cat.read_text())["schema"] == 1
        n0 = len(registry())
        loaded.install()
        assert len(registry()) >= n0
        # re-run resumes: everything covered, nothing new measured
        clear_extensions()
        rc = main(["--catalog", str(cat), "--grid", "tiny",
                   "--kernels", "conv_im2col", "--max-per-kernel", "4",
                   "--measure", "analytic"])
        assert rc == 0
        assert "measured 0," in capsys.readouterr().out


# ----------------------------------------------------------------------
# anytime solve over the widened registry
# ----------------------------------------------------------------------
class TestAnytimeOnWidenedRegistry:
    def test_deadline_respected_with_near_optimal_cost(self):
        """Regression for the solve->compile->serve fallback ladder:
        with the autotuned extension installed (>= 70 primitives) the
        anytime solver must return by its deadline with an incumbent
        within 10% of the exact optimum."""
        net = conv_tower((32, 32, 32), depth=3, width=32)
        cost = TPU_COST()
        res = tune(scenario_grid("tiny")
                   + scenarios_from_net(net, batches=(1,)),
                   measure_mode="analytic")
        res.catalog.install()
        assert len(registry()) >= 70
        exact = select_pbqp(net, cost)
        deadline = 0.5
        t0 = time.perf_counter()
        anytime = select_pbqp(net, cost, deadline_s=deadline)
        wall = time.perf_counter() - t0
        assert wall <= deadline + 0.5
        assert anytime.predicted_cost <= 1.1 * exact.predicted_cost
