"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, asserting output shapes + no NaNs.  Also
decode-equivalence (prefill+decode == full forward) for the serve path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import (
    ModelRuntime, ShardingPlan, decode_step, encode, forward_train,
    init_cache, init_params, loss_fn, param_count, prefill,
)

PLAN = ShardingPlan(mesh=None)
RT = ModelRuntime(attn_impl="xla", chunk=8)


def _smoke_cfg(name):
    return get_config(name).scaled_down()


def _batch(cfg, b=2, t=16, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, size=(b, t)),
                              jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, size=(b, t)),
                              jnp.int32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.enc_seq, cfg.d_model)) * 0.02,
            jnp.float32)
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_patches, cfg.d_model)) * 0.02,
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_forward_shapes_and_finite(arch):
    cfg = _smoke_cfg(arch)
    params = init_params(cfg, jax.random.key(0), jnp.float32)
    batch = _batch(cfg)
    logits = forward_train(cfg, params, batch, PLAN, RT)
    b, t = batch["tokens"].shape
    assert logits.shape == (b, t, cfg.vocab)
    assert bool(jnp.isfinite(logits).all()), f"{arch}: non-finite logits"


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_grads_finite(arch):
    cfg = _smoke_cfg(arch)
    params = init_params(cfg, jax.random.key(1), jnp.float32)
    batch = _batch(cfg)

    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch, PLAN, RT))(params)
    assert bool(jnp.isfinite(loss))
    gleaves = jax.tree.leaves(grads)
    assert gleaves
    finite = [bool(jnp.isfinite(g).all()) for g in gleaves]
    assert all(finite), f"{arch}: non-finite grads"
    # gradients actually flow (not all zero)
    total = sum(float(jnp.sum(jnp.abs(g))) for g in gleaves)
    assert total > 0


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "gemma2-9b",
                                  "mamba2-2.7b", "jamba-v0.1-52b",
                                  "grok-1-314b"])
def test_decode_matches_forward(arch):
    """prefill + decode_step must reproduce the full-forward logits."""
    cfg = _smoke_cfg(arch)
    params = init_params(cfg, jax.random.key(2), jnp.float32)
    b, t = 2, 12
    batch = _batch(cfg, b=b, t=t)
    full = forward_train(cfg, params, batch, PLAN, RT)

    # prefill on the first t-3 tokens, then decode 3 steps
    tp = t - 3
    pre = {"tokens": batch["tokens"][:, :tp]}
    logits, cache = prefill(cfg, params, pre, PLAN, RT, max_seq=t)
    np.testing.assert_allclose(
        np.asarray(logits[:, 0]), np.asarray(full[:, tp - 1]),
        rtol=2e-2, atol=2e-2)
    for i in range(3):
        pos = tp + i
        step_logits, cache = decode_step(
            cfg, params, cache, batch["tokens"][:, pos:pos + 1], pos,
            PLAN, RT)
        np.testing.assert_allclose(
            np.asarray(step_logits[:, 0]), np.asarray(full[:, pos]),
            rtol=2e-2, atol=2e-2,
            err_msg=f"{arch}: decode step {i} diverges")


def test_whisper_decode_with_cross_attention():
    cfg = _smoke_cfg("whisper-large-v3")
    params = init_params(cfg, jax.random.key(3), jnp.float32)
    batch = _batch(cfg, b=1, t=8)
    full = forward_train(cfg, params, batch, PLAN, RT)
    enc = encode(cfg, params, batch["frames"], PLAN, RT)
    pre = {"tokens": batch["tokens"][:, :6], "frames": batch["frames"]}
    logits, cache = prefill(cfg, params, pre, PLAN, RT, max_seq=8)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full[:, 5]), rtol=2e-2,
                               atol=2e-2)
    step_logits, cache = decode_step(cfg, params, cache,
                                     batch["tokens"][:, 6:7], 6, PLAN,
                                     RT, cross_kv=enc)
    np.testing.assert_allclose(np.asarray(step_logits[:, 0]),
                               np.asarray(full[:, 6]), rtol=2e-2,
                               atol=2e-2)


def test_param_counts_match_published_sizes():
    """Full configs must land near the published parameter counts."""
    expect = {
        "mistral-nemo-12b": (12e9, 0.10),
        # assigned-table d_ff=22528 gives 30.3B for the 35B card
        "command-r-35b": (35e9, 0.15),
        "tinyllama-1.1b": (1.1e9, 0.10),
        "gemma2-9b": (9e9, 0.15),
        "kimi-k2-1t-a32b": (1.0e12, 0.15),
        "grok-1-314b": (314e9, 0.10),
        "jamba-v0.1-52b": (52e9, 0.15),
        "mamba2-2.7b": (2.7e9, 0.15),
        "llava-next-34b": (34e9, 0.30),  # backbone-only vs full VLM
    }
    for arch, (target, tol) in expect.items():
        n = param_count(get_config(arch))
        assert abs(n - target) / target < tol, \
            f"{arch}: {n/1e9:.2f}B vs {target/1e9:.0f}B published"


def test_moe_active_params():
    from repro.models import active_param_count
    cfg = get_config("kimi-k2-1t-a32b")
    active = active_param_count(cfg)
    assert abs(active - 32e9) / 32e9 < 0.35, f"{active/1e9:.1f}B active"


def test_gemma2_softcaps_bound_logits():
    cfg = _smoke_cfg("gemma2-9b")
    params = init_params(cfg, jax.random.key(4), jnp.float32)
    batch = _batch(cfg)
    logits = forward_train(cfg, params, batch, PLAN, RT)
    assert float(jnp.max(jnp.abs(logits))) <= cfg.logit_softcap + 1e-3


def test_mamba2_chunk_invariance():
    """SSD chunked computation must not depend on the chunk size."""
    cfg = _smoke_cfg("mamba2-2.7b")
    params = init_params(cfg, jax.random.key(5), jnp.float32)
    batch = _batch(cfg, b=1, t=16)
    l4 = forward_train(cfg, params, batch, PLAN,
                       ModelRuntime(chunk=4))
    l16 = forward_train(cfg, params, batch, PLAN,
                        ModelRuntime(chunk=16))
    np.testing.assert_allclose(np.asarray(l4), np.asarray(l16),
                               rtol=2e-3, atol=2e-3)
    lu = forward_train(cfg, params, batch, PLAN,
                       ModelRuntime(chunk=4, unroll_chunks=True))
    np.testing.assert_allclose(np.asarray(l4), np.asarray(lu),
                               rtol=1e-5, atol=1e-5)
