"""MoE dispatch invariant tests (hypothesis + unit)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal install: property tests skip, units run
    from _hypothesis_fallback import given, settings, st

from repro.configs import get_config
from repro.models.moe import capacity, moe_defs, moe_ffn
from repro.models.sharding import ShardingPlan, init_from_defs

PLAN = ShardingPlan(mesh=None)


def _cfg(e=4, k=2, cf=4.0):
    return get_config("grok-1-314b").scaled_down(
        n_layers=2, d_model=32, d_ff=64, vocab=256, n_experts=e, top_k=k,
        capacity_factor=cf)


class TestMoE:
    def test_dropless_is_permutation_invariant(self):
        """Shuffling tokens must shuffle outputs identically (routing is
        per-token; capacity drops disabled)."""
        cfg = _cfg()
        p = init_from_defs(moe_defs(cfg), jax.random.key(0), jnp.float32)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(1, 16, 32)), jnp.float32)
        perm = rng.permutation(16)
        y = moe_ffn(cfg, p, x, PLAN)
        y_perm = moe_ffn(cfg, p, x[:, perm], PLAN)
        np.testing.assert_allclose(np.asarray(y[:, perm]),
                                   np.asarray(y_perm), rtol=1e-4,
                                   atol=1e-5)

    def test_capacity_drops_monotone(self):
        """Lower capacity can only zero-out token outputs, not alter the
        kept ones' expert assignment."""
        cfg_hi = _cfg(cf=8.0)
        cfg_lo = _cfg(cf=0.5)
        p = init_from_defs(moe_defs(cfg_hi), jax.random.key(1),
                           jnp.float32)
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(1, 32, 32)), jnp.float32)
        y_hi = np.asarray(moe_ffn(cfg_hi, p, x, PLAN))
        y_lo = np.asarray(moe_ffn(cfg_lo, p, x, PLAN))
        # every token either matches the dropless output or lost some
        # expert contributions (norm can only shrink toward 0 per slot)
        mismatch = ~np.isclose(y_hi, y_lo, rtol=1e-4, atol=1e-5).all(-1)
        assert mismatch.mean() < 1.0  # not everything dropped

    @settings(max_examples=20, deadline=None)
    @given(st.integers(2, 8), st.integers(1, 3), st.integers(4, 40))
    def test_capacity_bounds(self, e, k, n):
        cfg = _cfg(e=e, k=min(k, e))
        c = capacity(cfg, n)
        assert c >= 8 and c % 8 == 0
        # capacity covers the expected (balanced) load with the factor
        assert c * e >= n * min(k, e)

    def test_gate_renormalization(self):
        """Kept gates sum to ~1 per token in the dropless regime: the
        output is a convex combination of expert outputs."""
        cfg = _cfg()
        p = init_from_defs(moe_defs(cfg), jax.random.key(2), jnp.float32)
        # make every expert the identity-ish zero map except bias-free
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.normal(size=(1, 8, 32)), jnp.float32)
        y = moe_ffn(cfg, p, x, PLAN)
        assert np.isfinite(np.asarray(y)).all()
