"""PlanServer round-trip and bucketing tests (acceptance criteria)."""
import numpy as np
import pytest

from repro.core import plan as plan_mod
from repro.core.costs import AnalyticCostModel
from repro.serving import (
    BucketPolicy, PlanServer, bucket_key, bucket_shape, conv_tower,
)

CM = AnalyticCostModel()
POLICY = BucketPolicy(min_hw=8, max_hw=64)


def _server(tmp_path=None, **kw):
    kw.setdefault("policy", POLICY)
    kw.setdefault("lru_capacity", 4)
    return PlanServer(lambda s: conv_tower(s, depth=2, width=8), CM,
                      cache_dir=tmp_path, **kw)


class TestBucketing:
    def test_pow2_rounds_up(self):
        assert bucket_shape((3, 20, 20), POLICY) == (4, 32, 32)
        assert bucket_shape((4, 32, 32), POLICY) == (4, 32, 32)
        assert bucket_shape((5, 33, 17), POLICY) == (8, 64, 32)

    def test_clamps(self):
        assert bucket_shape((1, 2, 2), POLICY) == (1, 8, 8)
        # above the ceiling the request wins: round to the request, never crop
        assert bucket_shape((3, 100, 100), POLICY) == (4, 100, 100)

    def test_linear_mode(self):
        p = BucketPolicy(spatial="linear", channel="linear",
                         spatial_step=24, channel_step=4)
        assert bucket_shape((3, 25, 49), p) == (4, 48, 72)

    def test_exact_mode(self):
        p = BucketPolicy(spatial="exact", channel="exact")
        assert bucket_shape((3, 21, 37), p) == (3, 21, 37)

    def test_bad_shape_rejected(self):
        with pytest.raises(ValueError):
            bucket_shape((0, 4, 4), POLICY)
        with pytest.raises(ValueError):
            bucket_shape((3, 4), POLICY)  # type: ignore[arg-type]

    def test_bucket_key_stable(self):
        assert bucket_key((4, 32, 32)) == "c4h32w32"


class TestPlanServerRoundTrip:
    def test_same_bucket_one_solve_one_compile(self):
        """Acceptance: two requests in the same bucket trigger exactly one
        PBQP solve and one compile, asserted via counters."""
        srv = _server()
        c0 = plan_mod.compile_count()
        srv.infer(np.random.default_rng(0)
                  .normal(size=(3, 20, 20)).astype(np.float32))
        srv.infer(np.random.default_rng(1)
                  .normal(size=(3, 24, 28)).astype(np.float32))
        s = srv.stats()
        assert s["requests"] == 2
        assert s["solves"] == 1
        assert s["compiles"] == 1
        assert plan_mod.compile_count() - c0 == 1
        assert s["exec_hits"] == 1 and s["exec_misses"] == 1
        assert s["buckets"] == 1
        srv.close()

    def test_output_shape_independent_of_request_shape_in_bucket(self):
        srv = _server()
        o1 = srv.infer(np.zeros((3, 20, 20), np.float32))
        o2 = srv.infer(np.zeros((3, 27, 31), np.float32))
        assert {k: v.shape for k, v in o1.items()} == \
            {k: v.shape for k, v in o2.items()}
        srv.close()

    def test_second_bucket_warm_starts(self):
        # 20 -> bucket (4,32,32); 40 -> bucket (4,64,64): same topology,
        # so the second solve is seeded by the first bucket's optimum
        srv = _server()
        srv.infer(np.zeros((3, 20, 20), np.float32))
        srv.infer(np.zeros((3, 40, 40), np.float32))
        s = srv.stats()
        assert s["solves"] == 2
        assert s["warm_solves"] == 1
        assert s["buckets"] == 2
        srv.close()

    def test_disk_persistence_across_servers(self, tmp_path):
        srv = _server(tmp_path)
        srv.infer(np.zeros((3, 20, 20), np.float32))
        assert srv.stats()["disk_plans"] == 1
        srv.close()
        # a new process-equivalent: fresh server, same cache dir
        srv2 = _server(tmp_path)
        srv2.infer(np.zeros((3, 18, 22), np.float32))  # same bucket
        s = srv2.stats()
        assert s["solves"] == 0
        assert s["plan_disk_hits"] == 1
        assert s["compiles"] == 1  # executables are not persistable
        srv2.close()

    def test_cost_version_bump_invalidates_disk(self, tmp_path):
        srv = _server(tmp_path)
        srv.infer(np.zeros((3, 20, 20), np.float32))
        srv.close()
        from repro.core.costs import TPU_V5E_SPEC
        srv2 = PlanServer(lambda s: conv_tower(s, depth=2, width=8),
                          AnalyticCostModel(TPU_V5E_SPEC),
                          policy=POLICY, cache_dir=tmp_path)
        srv2.plan_for((3, 20, 20))
        s = srv2.stats()
        assert s["plan_disk_hits"] == 0
        assert s["solves"] == 1  # re-solved under the new cost model
        srv2.close()

    def test_lru_eviction_recompiles_but_reuses_plan(self):
        srv = _server(lru_capacity=1)
        srv.infer(np.zeros((3, 16, 16), np.float32))
        srv.infer(np.zeros((3, 48, 48), np.float32))  # evicts bucket 1
        srv.infer(np.zeros((3, 16, 16), np.float32))  # recompile, plan hit
        s = srv.stats()
        assert s["exec_evictions"] >= 1
        assert s["compiles"] == 3
        assert s["solves"] == 2          # plans survived the eviction
        assert s["plan_mem_hits"] == 1
        srv.close()

    def test_prefetch_async(self):
        srv = _server()
        fut = srv.prefetch((3, 20, 20))
        cnet = fut.result(timeout=120)
        assert cnet is srv.compiled_for((3, 20, 20))  # now a hit
        s = srv.stats()
        assert s["solves"] == 1 and s["compiles"] == 1
        srv.close()

    def test_plan_predictions_are_finite_and_optimal(self):
        srv = _server()
        sel = srv.plan_for((3, 20, 20))
        assert np.isfinite(sel.predicted_cost)
        assert sel.optimal
        srv.close()


class TestServeLoopVisionBridge:
    def test_pixels_become_prompt_tokens(self):
        import jax
        import jax.numpy as jnp
        from repro.configs import get_config
        from repro.models import init_params
        from repro.runtime import Request, ServeLoop

        cfg = get_config("tinyllama-1.1b").scaled_down(
            n_layers=2, d_model=64, d_ff=128, vocab=256)
        params = init_params(cfg, jax.random.key(0), jnp.float32)
        srv = _server()
        loop = ServeLoop(cfg, params, max_batch=2, max_seq=64,
                         plan_server=srv, image_tokens=3)
        rng = np.random.default_rng(0)
        reqs = [Request(rid=i, prompt=np.arange(4, dtype=np.int32),
                        max_new_tokens=2,
                        pixels=rng.normal(size=(3, 18, 18))
                        .astype(np.float32))
                for i in range(2)]
        loop.run(reqs)
        for r in reqs:
            assert r.done and len(r.tokens) == 2
            assert r.pixels is None
            assert len(r.prompt) == 4 + 3  # vision tokens prepended
            assert np.all(r.prompt[:3] < cfg.vocab)
        s = srv.stats()
        assert s["requests"] == 2 and s["solves"] == 1 \
            and s["compiles"] == 1
        loop.close()
        srv.close()


class TestNearestPlan:
    """Warm-start source selection (PlanServer._nearest_plan)."""

    def test_empty_cache_returns_none(self):
        srv = _server()
        assert srv._nearest_plan((4, 32, 32, 1)) is None
        srv.close()

    def test_exact_hit_is_distance_zero(self):
        srv = _server()
        sel = srv.plan_for((3, 16, 16))           # bucket (4, 16, 16), n=1
        assert srv._nearest_plan((4, 16, 16, 1)) is sel
        srv.close()

    def test_picks_nearest_in_log_shape_space(self):
        srv = _server()
        near = srv.plan_for((3, 16, 16))          # (4, 16, 16, 1)
        far = srv.plan_for((3, 60, 60))           # (4, 64, 64, 1)
        assert near is not far
        # query (4, 16, 16, 2): distance 1 to `near` (batch axis only),
        # distance 5 to `far` (two spatial doublings x2 + batch)
        assert srv._nearest_plan((4, 16, 16, 2)) is near
        # and the batch axis is one more axis of the metric: a batched
        # query near the big bucket prefers the big bucket
        assert srv._nearest_plan((4, 64, 64, 2)) is far
        srv.close()


class TestConcurrencyStress:
    def test_mixed_paths_under_eviction_lose_nothing(self):
        """Threaded hammer across every request path while the LRU
        churns: every issued request resolves exactly once with the
        correct output, and the counters account for every request."""
        import threading

        srv = PlanServer(lambda s: conv_tower(s, depth=2, width=4), CM,
                         policy=POLICY, lru_capacity=2)
        rng = np.random.default_rng(7)
        shapes = [(3, 12, 12), (3, 16, 16), (3, 20, 20)]  # buckets 16, 32
        imgs = [rng.normal(size=s).astype(np.float32) for s in shapes]
        # references (and the nb=1 warm-up) before the storm
        refs = [srv.infer(x) for x in imgs]
        base_requests = len(imgs)

        issued = [0]
        results = []          # (img_idx, output_dict)
        errors = []
        lock = threading.Lock()

        def record(i, out):
            with lock:
                results.append((i, out))

        def worker(tid):
            trng = np.random.default_rng(100 + tid)
            ops = ["infer", "batch", "queue", "prefetch"] * 2
            trng.shuffle(ops)
            try:
                for op in ops:
                    i = int(trng.integers(len(imgs)))
                    j = int(trng.integers(len(imgs)))
                    if op == "infer":
                        with lock:
                            issued[0] += 1
                        record(i, srv.infer(imgs[i]))
                    elif op == "batch":
                        with lock:
                            issued[0] += 2
                        out = srv.infer_batch([imgs[i], imgs[j]])
                        record(i, out[0])
                        record(j, out[1])
                    elif op == "queue":
                        with lock:
                            issued[0] += 1
                        fut = srv.enqueue(imgs[i])
                        srv.flush()  # drains everyone's pending, not just ours
                        record(i, fut.result(timeout=120))
                    else:
                        srv.prefetch(shapes[i],
                                     n=2 if i % 2 else 1).result(timeout=120)
            except BaseException as exc:  # noqa: BLE001 — surface in main
                errors.append(exc)

        threads = [threading.Thread(target=worker, args=(t,))
                   for t in range(6)]
        for th in threads:
            th.start()
        for th in threads:
            th.join(timeout=300)
        assert not errors, errors

        # no lost or duplicated results: one output per issued request
        assert len(results) == issued[0]
        for i, out in results:
            for k in refs[i]:
                np.testing.assert_allclose(out[k], refs[i][k],
                                           rtol=2e-3, atol=2e-3)
        s = srv.stats()
        assert s["requests"] == issued[0] + base_requests
        # capacity 2 with >= 4 live (bucket, batch) specs must churn
        assert s["exec_evictions"] >= 1
        # the plan tier never evicts: recompiles reuse solved plans
        assert s["solves"] <= 2 * 2  # 2 spatial buckets x 2 batch buckets
        srv.close()
