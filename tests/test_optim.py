"""Optimizer unit tests (AdamW, Adafactor, clipping, schedule)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (
    adafactor, adamw, clip_by_global_norm, global_norm, warmup_cosine,
)


def _quadratic_descent(opt, steps=60):
    target = jnp.asarray(np.random.default_rng(0).normal(size=(4, 256)),
                         jnp.float32)
    params = {"w": jnp.zeros((4, 256), jnp.float32),
              "b": jnp.zeros((4,), jnp.float32)}
    state = opt.init(params)

    def loss_fn(p):
        return jnp.mean((p["w"] + p["b"][:, None] - target) ** 2)

    losses = []
    for _ in range(steps):
        loss, g = jax.value_and_grad(loss_fn)(params)
        params, state = opt.update(g, state, params)
        losses.append(float(loss))
    return losses


def test_adamw_converges():
    losses = _quadratic_descent(adamw(lambda s: 0.05, weight_decay=0.0))
    assert losses[-1] < 0.05 * losses[0]


def test_adafactor_converges():
    losses = _quadratic_descent(adafactor(lambda s: 0.05))
    assert losses[-1] < 0.2 * losses[0]


def test_adafactor_state_is_factored():
    opt = adafactor(lambda s: 1e-3)
    params = {"big": jnp.zeros((256, 512)), "small": jnp.zeros((4, 8)),
              "vec": jnp.zeros((300,))}
    st = opt.init(params)
    assert set(st["f"]["big"]) == {"r", "c"}
    assert st["f"]["big"]["r"].shape == (256,)
    assert st["f"]["big"]["c"].shape == (512,)
    assert set(st["f"]["small"]) == {"v"}      # below min_dim: unfactored
    assert set(st["f"]["vec"]) == {"v"}


def test_clip_by_global_norm():
    tree = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(10 * 9 + 10 * 16))
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    # under the limit: untouched
    same, _ = clip_by_global_norm(tree, 1e9)
    np.testing.assert_array_equal(same["a"], tree["a"])


def test_warmup_cosine_shape():
    lr = warmup_cosine(1.0, warmup=10, total=100, floor=0.1)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0)
    assert float(lr(100)) == pytest.approx(0.1, rel=1e-2)
    assert float(lr(5)) == pytest.approx(0.5)
