"""Reliability-layer tests (PR 9 acceptance).

The contract under test, per docs/reliability.md: faults are injected
deterministically and replayably; a solve that cannot finish degrades
down the ladder (anytime -> greedy -> reference) instead of failing the
request; corrupt plan-cache entries are misses, never errors; compiles
retry with bounded backoff and demote the plan as a last resort; a
crashing/NaN kernel trips a per-(primitive, bucket) breaker whose
re-solve excludes it and whose release restores the original plan; and
a scheduler with shedding enabled rejects unmeetable deadlines at
admission with a typed error.
"""
import json
import pathlib
import time

import numpy as np
import pytest

from repro.core.costs import AnalyticCostModel
from repro.core.pbqp import PBQP, solve
from repro.core.selection import select_local_optimal, select_pbqp
from repro.reliability import (
    FallbackLadder, FaultInjector, FaultSpec, InjectedFault,
    KernelFailure, PrimitiveQuarantine, ShedError, diagnose_nonfinite,
    parse_fault_plan, reference_selection, retry_call,
)
from repro.serving import (
    BucketPolicy, ContinuousScheduler, PlanDiskCache, PlanServer,
    conv_tower,
)

CM = AnalyticCostModel()
POLICY = BucketPolicy(min_hw=8, max_hw=64, max_n=4)


def _server(**kw):
    kw.setdefault("policy", POLICY)
    kw.setdefault("lru_capacity", 8)
    kw.setdefault("compile_backoff_s", 0.001)
    return PlanServer(lambda s: conv_tower(s, depth=2, width=4), CM,
                      **kw)


def _injector(plan: str, seed: int = 0) -> FaultInjector:
    return FaultInjector(parse_fault_plan(plan), seed=seed)


def _dense_pbqp(seed: int, n: int = 9, k: int = 4) -> PBQP:
    """B&B-heavy instance (reductions alone cannot finish it)."""
    rng = np.random.default_rng(seed)
    pb = PBQP()
    for i in range(n):
        pb.add_node(i, rng.uniform(1, 100, size=k))
    for i in range(n):
        for j in range(i + 1, n):
            pb.add_edge(i, j, rng.uniform(0, 50, size=(k, k)))
    return pb


def _prims(sel):
    return sorted({c.primitive.name for c in sel.choices.values()
                   if c.primitive is not None})


def _nanify(node_params):
    """NaN-poison every float leaf of one node's packed parameters."""
    import jax
    return jax.tree.map(
        lambda v: np.full_like(v, np.nan)
        if np.issubdtype(np.asarray(v).dtype, np.floating) else v,
        node_params)


# ======================================================================
# anytime branch-and-bound
# ======================================================================
class TestAnytimeSolve:
    def test_expired_deadline_returns_best_so_far(self):
        pb = _dense_pbqp(1)
        exact = solve(pb, exact=True)
        anytime = solve(pb, exact=True, deadline_s=0.0)
        assert not anytime.optimal
        assert anytime.stats["DEADLINE"] == 1
        # a full, valid assignment — degraded in proof, not in shape
        assert set(anytime.assignment) == set(pb.nodes)
        assert np.isfinite(anytime.cost)
        assert anytime.cost >= exact.cost - 1e-9

    def test_generous_deadline_stays_exact(self):
        pb = _dense_pbqp(1)
        sol = solve(pb, exact=True, deadline_s=60.0)
        assert sol.optimal
        assert sol.stats.get("DEADLINE", 0) == 0

    def test_no_deadline_is_unchanged(self):
        pb = _dense_pbqp(0)
        assert solve(pb, exact=True).optimal

    def test_selection_threads_deadline_through(self):
        net = conv_tower((4, 16, 16), depth=2, width=4)
        sel = select_pbqp(net, CM, deadline_s=60.0)
        assert sel.optimal  # tiny instance: deadline never binds


# ======================================================================
# fault injector
# ======================================================================
class TestFaultInjector:
    def test_window_semantics(self):
        inj = FaultInjector([FaultSpec("compile", start=1, count=2)])
        fired = [inj.check("compile") is not None for _ in range(5)]
        assert fired == [False, True, True, False, False]

    def test_deterministic_replay(self):
        plan = (FaultSpec("kernel", kind="nan", p=0.5, count=0),)
        a = FaultInjector(plan, seed=7)
        b = FaultInjector(plan, seed=7)
        ticks_a = [a.check("kernel") is not None for _ in range(50)]
        ticks_b = [b.check("kernel") is not None for _ in range(50)]
        assert ticks_a == ticks_b
        assert any(ticks_a) and not all(ticks_a)

    def test_match_filters_by_key(self):
        inj = FaultInjector([FaultSpec("kernel", match="winograd",
                                       count=0)])
        assert inj.check("kernel", key="direct_lax") is None
        assert inj.check("kernel", key="winograd_f2") is not None

    def test_sites_isolated(self):
        inj = FaultInjector([FaultSpec("compile", start=0, count=1)])
        assert inj.check("solve") is None       # does not tick compile
        assert inj.check("compile") is not None

    def test_fired_log_records_history(self):
        inj = FaultInjector([FaultSpec("compile", count=1)])
        inj.check("compile", key="b1")
        assert inj.fired == [("compile", "raise", 0, "b1")]

    def test_raise_if_raises_typed_error(self):
        inj = FaultInjector([FaultSpec("compile", count=1)])
        with pytest.raises(InjectedFault) as ei:
            inj.raise_if("compile", key="b1")
        assert ei.value.site == "compile"

    def test_parse_inline_dsl(self):
        specs = parse_fault_plan(
            "kernel:nan@5+3~winograd,compile:raise@0+2,"
            "solve:budget@1=5000")
        assert specs[0] == FaultSpec("kernel", kind="nan", start=5,
                                     count=3, match="winograd")
        assert specs[1] == FaultSpec("compile", kind="raise", start=0,
                                     count=2)
        assert specs[2].value == 5000.0

    def test_parse_json_file(self, tmp_path):
        p = tmp_path / "plan.json"
        p.write_text(json.dumps(
            [{"site": "worker", "kind": "raise", "start": 3}]))
        specs = parse_fault_plan(str(p))
        assert specs == [FaultSpec("worker", start=3)]

    def test_invalid_site_rejected(self):
        with pytest.raises(ValueError):
            FaultSpec("nonsense")
        with pytest.raises(ValueError):
            parse_fault_plan("nonsense:raise")


# ======================================================================
# retry helper
# ======================================================================
class TestRetryCall:
    def test_retries_then_succeeds(self):
        calls = []

        def flaky():
            calls.append(1)
            if len(calls) < 3:
                raise RuntimeError("transient")
            return "ok"

        assert retry_call(flaky, retries=2, base_delay_s=0.0) == "ok"
        assert len(calls) == 3

    def test_exhausted_reraises_and_backoff_grows(self):
        sleeps = []
        calls = []

        def always_fails():
            calls.append(1)
            raise RuntimeError("permanent")

        import repro.reliability.fallback as fb
        orig = fb.time.sleep
        fb.time.sleep = sleeps.append
        try:
            with pytest.raises(RuntimeError, match="permanent"):
                retry_call(always_fails, retries=2, base_delay_s=0.01)
        finally:
            fb.time.sleep = orig
        assert len(calls) == 3
        assert len(sleeps) == 2
        assert sleeps[1] > sleeps[0]  # exponential with jitter in [1,2)


# ======================================================================
# corrupt plan cache (satellite regression)
# ======================================================================
class TestCorruptPlanCache:
    def _seed_cache(self, tmp_path):
        srv = _server(cache_dir=tmp_path)
        srv.plan_for((3, 16, 16))
        srv.close()
        return next(pathlib.Path(tmp_path).glob("plan_*.json"))

    def test_truncated_payload_is_miss_delete_resolve(self, tmp_path):
        f = self._seed_cache(tmp_path)
        raw = f.read_text()
        f.write_text(raw[:len(raw) // 2])   # hand-truncated payload
        srv = _server(cache_dir=tmp_path)
        sel = srv.plan_for((3, 16, 16))
        s = srv.stats()
        assert sel.optimal
        assert s["plan_cache_corrupt"] == 1
        assert s["plan_disk_hits"] == 0 and s["solves"] == 1
        # bad file was deleted and the re-solve re-persisted a good one
        assert json.loads(f.read_text())["schema"] is not None
        srv.close()

    def test_schema_mismatch_is_corrupt_not_error(self, tmp_path):
        f = self._seed_cache(tmp_path)
        payload = json.loads(f.read_text())
        payload["schema"] = 1  # ancient plan format
        f.write_text(json.dumps(payload))
        srv = _server(cache_dir=tmp_path)
        srv.plan_for((3, 16, 16))
        assert srv.stats()["plan_cache_corrupt"] == 1
        srv.close()

    def test_non_dict_payload_is_corrupt(self, tmp_path):
        f = self._seed_cache(tmp_path)
        f.write_text("[1, 2, 3]")
        srv = _server(cache_dir=tmp_path)
        srv.plan_for((3, 16, 16))
        assert srv.stats()["plan_cache_corrupt"] == 1
        srv.close()

    def test_on_corrupt_callback_and_counter(self, tmp_path):
        cache = PlanDiskCache(tmp_path, on_corrupt=lambda k: seen.append(k))
        seen = []
        cache.put("abc", {"schema": -1})
        assert cache.get("abc") is None
        assert cache.corrupt == 1 and seen == ["abc"]

    def test_injected_corruption_truncates_real_file(self, tmp_path):
        self._seed_cache(tmp_path)
        srv = _server(cache_dir=tmp_path,
                      fault_injector=_injector("plan_cache:corrupt@0+1"))
        srv.plan_for((3, 16, 16))
        assert srv.stats()["plan_cache_corrupt"] == 1
        srv.close()


# ======================================================================
# fallback ladder
# ======================================================================
class TestFallbackLadder:
    def test_reference_selection_executes_and_matches(self):
        from repro.core.plan import compile_plan
        net = conv_tower((3, 16, 16), depth=2, width=4)
        ref = reference_selection(net, CM)
        assert ref.strategy == "reference" and not ref.optimal
        exact = select_pbqp(net, CM)
        params = net.init_params(0)
        x = np.random.default_rng(0).normal(size=(3, 16, 16)) \
            .astype(np.float32)
        out_ref = compile_plan(ref, params)(x)
        out_exact = compile_plan(exact, params)(x)
        for nid in out_exact:
            np.testing.assert_allclose(out_ref[nid], out_exact[nid],
                                       rtol=1e-4, atol=1e-5)

    def test_solve_fault_demotes_to_greedy(self):
        lad = FallbackLadder(CM,
                             fault_injector=_injector("solve:raise@0+1"))
        net = conv_tower((4, 16, 16), depth=2, width=4)
        sel, rung = lad.select(net, bucket="b")
        assert rung == "greedy"
        assert sel.strategy == "local_optimal"
        # next solve is healthy again
        _, rung2 = lad.select(net, bucket="b")
        assert rung2 == "exact"

    def test_rung_counters_bump(self):
        from repro.serving.metrics import ServingCounters
        ctr = ServingCounters()
        lad = FallbackLadder(CM, counters=ctr,
                             fault_injector=_injector("solve:raise@0+1"))
        net = conv_tower((4, 16, 16), depth=2, width=4)
        lad.select(net, bucket="b")
        lad.select(net, bucket="b")
        snap = ctr.snapshot()
        assert snap["ladder_greedy"] == 1
        assert snap["ladder_exact"] == 1
        assert snap["ladder_demotions"] == 1

    def test_server_serves_correct_output_from_greedy_rung(self):
        x = np.random.default_rng(0).normal(size=(3, 16, 16)) \
            .astype(np.float32)
        srv = _server()
        healthy = srv.infer(x)
        srv.close()
        srv = _server(fault_injector=_injector("solve:raise@0+1"))
        out = srv.infer(x)
        assert srv.stats()["ladder_greedy"] == 1
        for nid in healthy:
            np.testing.assert_allclose(out[nid], healthy[nid],
                                       rtol=1e-3, atol=1e-5)
        srv.close()


# ======================================================================
# compile retry + demotion
# ======================================================================
class TestCompileRetry:
    def test_transient_failure_retries_and_counts(self):
        srv = _server(fault_injector=_injector("compile:raise@0+2"))
        out = srv.infer(np.zeros((3, 16, 16), np.float32))
        s = srv.stats()
        assert s["compile_retries"] == 2
        assert s["compile_fallbacks"] == 0
        assert all(np.isfinite(v).all() for v in out.values())
        srv.close()

    def test_persistent_failure_demotes_plan(self):
        # 3 failures = 1 + compile_retries(2) attempts: the exact plan
        # never compiles, the greedy fallback does
        srv = _server(fault_injector=_injector("compile:raise@0+3"))
        out = srv.infer(np.zeros((3, 16, 16), np.float32))
        s = srv.stats()
        assert s["compile_fallbacks"] == 1
        assert s["ladder_greedy"] == 1
        assert all(np.isfinite(v).all() for v in out.values())
        srv.close()

    def test_unrecoverable_compile_raises(self):
        # every attempt of both the exact and the fallback plan fails
        srv = _server(fault_injector=_injector("compile:raise@0+6"),
                      compile_retries=1)
        with pytest.raises(InjectedFault):
            srv.infer(np.zeros((3, 16, 16), np.float32))
        srv.close()


# ======================================================================
# quarantine
# ======================================================================
class TestQuarantineUnit:
    def test_threshold_and_release(self):
        q = PrimitiveQuarantine(threshold=2)
        assert not q.record_failure("p", "b")
        assert q.record_failure("p", "b")       # second failure trips
        assert q.is_quarantined("p", "b")
        assert q.banned_for("b") == frozenset({"p"})
        assert q.banned_for("other") == frozenset()
        assert q.release("p", "b")
        assert not q.release("p", "b")          # already released
        assert not q.record_failure("p", "b")   # count was reset

    def test_version_token_rotates_and_recovers(self):
        q = PrimitiveQuarantine()
        assert q.version_token("b") == ""
        q.record_failure("p", "b")
        tok = q.version_token("b")
        assert tok.startswith("+quar=")
        assert q.version_token("other") == ""
        q.release("p", "b")
        assert q.version_token("b") == ""       # original keys again

    def test_diagnose_blames_nan_kernel(self):
        from repro.core.plan import compile_plan
        net = conv_tower((3, 16, 16), depth=2, width=4)
        sel = select_pbqp(net, CM)
        cnet = compile_plan(sel, net.init_params(0))
        x = np.random.default_rng(0).normal(size=(3, 16, 16)) \
            .astype(np.float32)
        assert diagnose_nonfinite(cnet, x) is None  # healthy
        first_conv = next(n.id for n in sel.net.conv_nodes())
        cnet.params[first_conv] = _nanify(cnet.params[first_conv])
        assert diagnose_nonfinite(cnet, x) == \
            sel.choices[first_conv].primitive.name


class TestQuarantineEndToEnd:
    def test_trip_resolve_release_cycle(self, tmp_path):
        x = np.random.default_rng(0).normal(size=(3, 16, 16)) \
            .astype(np.float32)
        srv = _server(cache_dir=tmp_path)
        healthy = srv.infer(x)
        prims0 = _prims(srv.plan_for(x.shape))
        srv.close()

        target = prims0[0]
        srv = _server(cache_dir=tmp_path,
                      fault_injector=_injector(f"kernel:nan@0+1~{target}"))
        out = srv.infer(x)          # NaN -> trip -> re-solve -> retry
        s = srv.stats()
        assert s["kernel_failures"] == 1 and s["quarantines"] == 1
        assert s["quarantined"] and target in s["quarantined"][0]
        for nid in healthy:         # the request still answered right
            np.testing.assert_allclose(out[nid], healthy[nid],
                                       rtol=1e-3, atol=1e-5)
        assert target not in _prims(srv.plan_for(x.shape))

        hits = srv.stats()["plan_disk_hits"]
        assert srv.release_quarantine(target, x.shape)
        assert _prims(srv.plan_for(x.shape)) == prims0
        # recovery keyed back onto the ORIGINAL persisted plan: a disk
        # hit, not a re-solve
        assert srv.stats()["plan_disk_hits"] == hits + 1
        srv.close()

    def test_unattributable_failure_raises_kernel_failure(self):
        # kernel fault with kind=raise and no match: culprit is the
        # plan's first primitive, quarantine still recovers; but an
        # exhausted retry budget surfaces the typed error
        srv = _server(fault_injector=_injector("kernel:raise@0+9"),
                      kernel_retries=1)
        with pytest.raises((InjectedFault, KernelFailure)):
            srv.infer(np.zeros((3, 16, 16), np.float32))
        assert srv.stats()["kernel_failures"] >= 1
        srv.close()

    def test_real_nan_attributed_and_quarantined(self):
        # no injector at all: poison the compiled executable's params
        # so the kernel REALLY emits NaN, then let the guard attribute
        # and quarantine it
        x = np.random.default_rng(0).normal(size=(3, 16, 16)) \
            .astype(np.float32)
        srv = _server()
        cnet = srv.compiled_for(x.shape)
        first_conv = next(n.id for n in cnet.sel.net.conv_nodes())
        cnet.params[first_conv] = _nanify(cnet.params[first_conv])
        out = srv.infer(x)
        s = srv.stats()
        assert s["quarantines"] == 1
        assert all(np.isfinite(v).all() for v in out.values())
        srv.close()


# ======================================================================
# load shedding
# ======================================================================
class TestLoadShedding:
    def test_unmeetable_deadline_shed_at_admission(self):
        srv = _server()
        sched = ContinuousScheduler(srv, batch_window_s=0.01, shed=True)
        sched.prewarm([(3, 16, 16)])
        x = np.zeros((3, 16, 16), np.float32)
        with pytest.raises(ShedError) as ei:
            sched.submit(x, slo_s=1e-12)
        assert ei.value.eta_s > 0
        assert sched.stats()["shed_requests"] == 1
        # a feasible deadline is admitted and served
        out = sched.submit(x, slo_s=60.0).result(timeout=60)
        assert all(np.isfinite(v).all() for v in out.values())
        sched.close()
        srv.close()

    def test_shed_off_by_default(self):
        srv = _server()
        sched = ContinuousScheduler(srv, batch_window_s=0.01)
        sched.prewarm([(3, 16, 16)])
        x = np.zeros((3, 16, 16), np.float32)
        # hopeless deadline: admitted anyway, counted as a miss
        out = sched.submit(x, slo_s=1e-12).result(timeout=60)
        s = sched.stats()
        assert s["shed_requests"] == 0
        assert s["deadline_miss"] == 1
        assert all(np.isfinite(v).all() for v in out.values())
        sched.close()
        srv.close()

    def test_deadline_less_requests_never_shed(self):
        srv = _server()
        sched = ContinuousScheduler(srv, batch_window_s=0.01, shed=True,
                                    shed_safety=1e9)
        sched.prewarm([(3, 16, 16)])
        out = sched.submit(np.zeros((3, 16, 16), np.float32)) \
            .result(timeout=60)
        assert sched.stats()["shed_requests"] == 0
        assert all(np.isfinite(v).all() for v in out.values())
        sched.close()
        srv.close()
