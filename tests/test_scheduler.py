"""ContinuousScheduler + ElasticController tests (PR 7 acceptance).

The scheduler's contract: requests coalesce continuously (window
trigger), SLO-carrying requests launch partial batches early (deadline
trigger), full groups launch immediately (full trigger), outputs are
bit-compatible with ``PlanServer.infer``, no submitted future is ever
lost (drain-on-close), and the elastic policy resizes the worker pool
deterministically from backlog pressure.
"""
import time
from concurrent.futures import CancelledError

import numpy as np
import pytest

from repro.core.costs import AnalyticCostModel
from repro.runtime.elastic import ElasticController
from repro.serving import (
    BucketPolicy, ContinuousScheduler, PlanServer, conv_tower,
)

CM = AnalyticCostModel()
POLICY = BucketPolicy(min_hw=8, max_hw=64, max_n=4)


def _server(**kw):
    kw.setdefault("policy", POLICY)
    kw.setdefault("lru_capacity", 8)
    return PlanServer(lambda s: conv_tower(s, depth=2, width=4), CM, **kw)


def _sched(srv, **kw):
    kw.setdefault("batch_window_s", 0.05)
    kw.setdefault("elastic",
                  ElasticController(min_workers=1, max_workers=3))
    return ContinuousScheduler(srv, **kw)


class TestTriggers:
    def test_window_coalesces_burst_into_one_batch(self):
        srv = _server()
        sched = _sched(srv)
        sched.prewarm([(3, 16, 16)], batches=(1, 2))
        rng = np.random.default_rng(0)
        f1 = sched.submit(rng.normal(size=(3, 14, 14)).astype(np.float32))
        f2 = sched.submit(rng.normal(size=(3, 15, 15)).astype(np.float32))
        f1.result(timeout=60)
        f2.result(timeout=60)
        s = sched.stats()
        assert s["sched_batches"] == 1
        assert s["sched_window_launches"] == 1
        assert s["coalesced"] == 1          # 2 requests, 1 invocation
        sched.close()
        srv.close()

    def test_deadline_launches_partial_batch_early(self):
        srv = _server()
        sched = _sched(srv, batch_window_s=5.0)  # window out of play
        sched.prewarm([(3, 16, 16)], batches=(1,))
        x = np.zeros((3, 16, 16), np.float32)
        t0 = time.perf_counter()
        fut = sched.submit(x, slo_s=0.05)
        fut.result(timeout=60)
        dt = time.perf_counter() - t0
        s = sched.stats()
        assert s["sched_deadline_launches"] == 1
        assert dt < 1.0, f"deadline trigger never fired ({dt:.2f}s)"
        sched.close()
        srv.close()

    def test_full_group_launches_without_waiting(self):
        srv = _server()
        sched = _sched(srv, batch_window_s=10.0)  # window out of play
        sched.prewarm([(3, 16, 16)], batches=(POLICY.max_n,))
        x = np.zeros((3, 16, 16), np.float32)
        t0 = time.perf_counter()
        futs = sched.submit_many([x] * POLICY.max_n)
        for f in futs:
            f.result(timeout=60)
        dt = time.perf_counter() - t0
        s = sched.stats()
        assert s["sched_full_launches"] >= 1
        assert dt < 5.0, "full group waited for the window"
        sched.close()
        srv.close()

    def test_deadline_accounting_feeds_goodput(self):
        srv = _server()
        sched = _sched(srv, batch_window_s=0.005)
        sched.prewarm([(3, 16, 16)], batches=(1, 2))
        x = np.zeros((3, 16, 16), np.float32)
        sched.submit(x, slo_s=30.0).result(timeout=60)   # will be met
        sched.submit(x, slo_s=1e-9).result(timeout=60)   # already lapsed
        s = sched.stats()
        assert s["deadline_met"] == 1
        assert s["deadline_miss"] == 1
        assert s["goodput"] == pytest.approx(0.5)
        sched.close()
        srv.close()


class TestCorrectnessAndLifecycle:
    def test_outputs_match_infer(self):
        srv = _server()
        sched = _sched(srv, batch_window_s=0.005)
        rng = np.random.default_rng(3)
        xs = [rng.normal(size=(3, hw, hw)).astype(np.float32)
              for hw in (12, 16, 20)]
        refs = [srv.infer(x) for x in xs]
        outs = [f.result(timeout=120)
                for f in sched.submit_many(list(xs))]
        for ref, out in zip(refs, outs):
            assert set(out) == set(ref)
            for k in ref:
                np.testing.assert_allclose(out[k], ref[k],
                                           rtol=2e-3, atol=2e-3)
        sched.close()
        srv.close()

    def test_bad_input_rejected(self):
        srv = _server()
        sched = _sched(srv)
        with pytest.raises(ValueError, match=r"\(C, H, W\)"):
            sched.submit(np.zeros((16, 16), np.float32))
        sched.close()
        srv.close()

    def test_close_drains_queued_work(self):
        srv = _server()
        sched = _sched(srv, batch_window_s=30.0)  # nothing launches alone
        sched.prewarm([(3, 16, 16)], batches=(1, 2))
        futs = sched.submit_many(
            [np.zeros((3, 16, 16), np.float32)] * 2)
        sched.close(drain=True)
        for f in futs:
            assert f.result(timeout=1) is not None  # resolved, not hung
        srv.close()

    def test_close_without_drain_cancels(self):
        srv = _server()
        sched = _sched(srv, batch_window_s=30.0)
        sched.prewarm([(3, 16, 16)], batches=(1,))
        fut = sched.submit(np.zeros((3, 16, 16), np.float32))
        sched.close(drain=False)
        assert fut.cancelled()
        with pytest.raises(CancelledError):
            fut.result(timeout=1)
        srv.close()

    def test_submit_after_close_raises(self):
        srv = _server()
        sched = _sched(srv)
        sched.close()
        with pytest.raises(RuntimeError, match="closed"):
            sched.submit(np.zeros((3, 16, 16), np.float32))
        srv.close()

    def test_stats_expose_scheduler_view(self):
        srv = _server()
        sched = _sched(srv)
        s = sched.stats()
        for key in ("sched_queued", "sched_inflight", "sched_workers",
                    "sched_submits", "goodput"):
            assert key in s
        assert s["sched_queued"] == 0 and s["sched_inflight"] == 0
        assert s["goodput"] == 1.0  # no deadlines seen yet
        sched.close()
        srv.close()


class TestModeledLatency:
    def test_prediction_then_observation(self):
        """The latency model is predicted-until-measured: cost-model
        prediction for a cold bucket, per-bucket execute p95 once the
        bucket has real samples."""
        from repro.serving import bucket_key
        from repro.serving.metrics import LATENCY_METRIC

        srv = _server()
        sched = _sched(srv, min_model_samples=3)
        bshape = (4, 16, 16)
        cold = sched._modeled_latency(bshape, 1)
        assert np.isfinite(cold) and cold > 0
        assert cold == pytest.approx(
            max(float(srv.plan_for(bshape).predicted_cost), 1e-6))
        # feed 3 observed execute samples well away from the prediction
        for _ in range(3):
            srv.counters.add(_bucket=bucket_key(bshape, 1),
                             execute_s=0.25)
        h = srv.counters.registry.find_histogram(
            LATENCY_METRIC, phase="execute", bucket=bucket_key(bshape, 1))
        assert h is not None and h.count == 3
        warm = sched._modeled_latency(bshape, 1)
        assert warm == pytest.approx(0.25, rel=0.2)
        sched.close()
        srv.close()


class TestElasticPolicy:
    def test_scales_up_immediately_under_pressure(self):
        ec = ElasticController(min_workers=1, max_workers=4,
                               scale_up_backlog=2.0)
        assert ec.workers == 1
        g0 = ec.generation
        assert ec.desired_workers(queued=10, inflight=1) == 2
        assert ec.desired_workers(queued=10, inflight=2) == 3
        assert ec.generation == g0 + 2

    def test_scale_down_needs_sustained_calm(self):
        ec = ElasticController(min_workers=1, max_workers=4, cooldown=3,
                               scale_down_backlog=0.5)
        for _ in range(3):
            ec.desired_workers(queued=20, inflight=0)
        assert ec.workers == 4
        # two calm rounds: still 4 (cooldown is 3)
        assert ec.desired_workers(queued=0, inflight=0) == 4
        assert ec.desired_workers(queued=0, inflight=0) == 4
        # third consecutive calm round shrinks by one
        assert ec.desired_workers(queued=0, inflight=0) == 3
        # a load blip resets the calm streak
        ec.desired_workers(queued=0, inflight=0)
        ec.desired_workers(queued=20, inflight=0)        # blip (scales up)
        assert ec.desired_workers(queued=0, inflight=0) == 4
        assert ec.workers == 4                           # streak restarted

    def test_bounds_validated_and_respected(self):
        with pytest.raises(ValueError):
            ElasticController(min_workers=0)
        with pytest.raises(ValueError):
            ElasticController(min_workers=3, max_workers=2)
        ec = ElasticController(min_workers=2, max_workers=2)
        assert ec.desired_workers(queued=100, inflight=0) == 2
        for _ in range(10):
            assert ec.desired_workers(queued=0, inflight=0) == 2

    def test_scheduler_mirrors_target_into_server_pool(self):
        srv = _server(max_workers=2)
        sched = _sched(srv, batch_window_s=0.005,
                       elastic=ElasticController(min_workers=1,
                                                 max_workers=3))
        # construction applies the controller's initial target
        assert srv.worker_target == 1
        # a backlog burst must scale the pool up within a few rounds
        sched.prewarm([(3, 16, 16)], batches=(1, 2, 4))
        rng = np.random.default_rng(0)
        futs = sched.submit_many(
            [rng.normal(size=(3, 16, 16)).astype(np.float32)
             for _ in range(24)])
        for f in futs:
            f.result(timeout=120)
        s = sched.stats()
        assert s["worker_resizes"] >= 1
        assert srv.worker_target > 1
        sched.close()
        srv.close()


class TestServeLoopOpenLoop:
    def test_arrival_offsets_are_honoured(self):
        import jax
        import jax.numpy as jnp
        from repro.configs import get_config
        from repro.models import init_params
        from repro.runtime import Request, ServeLoop

        cfg = get_config("tinyllama-1.1b").scaled_down(
            n_layers=2, d_model=64, d_ff=128, vocab=256)
        params = init_params(cfg, jax.random.key(0), jnp.float32)
        loop = ServeLoop(cfg, params, max_batch=2, max_seq=48)
        reqs = [Request(rid=i, prompt=np.arange(4, dtype=np.int32),
                        max_new_tokens=2, arrival_s=0.1 * i)
                for i in range(3)]
        t0 = time.perf_counter()
        loop.run(reqs)
        wall = time.perf_counter() - t0
        assert all(r.done for r in reqs)
        assert wall >= 0.2  # the last arrival gated the run
        loop.close()


class TestWorkerDeath:
    """Injected worker-slot deaths mid-dispatch (PR 9 reliability)."""

    def _chaos_sched(self, plan: str):
        from repro.reliability import FaultInjector, parse_fault_plan
        srv = _server(fault_injector=FaultInjector(parse_fault_plan(plan)))
        sched = ContinuousScheduler(srv, batch_window_s=0.01)
        sched.prewarm([(3, 16, 16)], batches=(1, 2))
        return srv, sched

    def test_death_requeues_group_and_completes(self):
        srv, sched = self._chaos_sched("worker:raise@0+1")
        x = np.random.default_rng(0).normal(size=(3, 16, 16)) \
            .astype(np.float32)
        healthy = srv.infer(x)
        out = sched.submit(x).result(timeout=60)
        s = sched.stats()
        assert s["worker_deaths"] == 1
        assert s["worker_requeues"] == 1
        for nid in healthy:
            np.testing.assert_allclose(out[nid], healthy[nid],
                                       rtol=1e-5, atol=1e-6)
        sched.close()
        srv.close()

    def test_second_death_poisons_the_request(self):
        from repro.reliability import InjectedFault
        srv, sched = self._chaos_sched("worker:raise@0+2")
        x = np.zeros((3, 16, 16), np.float32)
        fut = sched.submit(x)
        with pytest.raises(InjectedFault):
            fut.result(timeout=60)
        s = sched.stats()
        assert s["worker_deaths"] == 2
        assert s["worker_requeues"] == 1  # requeued once, then poison
        sched.close()
        srv.close()

    def test_death_in_coalesced_group_requeues_all(self):
        srv, sched = self._chaos_sched("worker:raise@0+1")
        xs = [np.random.default_rng(i).normal(size=(3, 16, 16))
              .astype(np.float32) for i in range(2)]
        healthy = [srv.infer(x) for x in xs]
        futs = sched.submit_many(xs)
        outs = [f.result(timeout=60) for f in futs]
        s = sched.stats()
        assert s["worker_deaths"] >= 1
        assert s["worker_requeues"] >= 1
        for h, out in zip(healthy, outs):
            for nid in h:
                np.testing.assert_allclose(out[nid], h[nid],
                                           rtol=1e-5, atol=1e-6)
        sched.close()
        srv.close()


class TestLifecycleRaces:
    """close()/resize_workers() racing in-flight bucket groups."""

    def test_close_races_inflight_groups(self):
        # a burst across two buckets is still in flight when close()
        # lands; drain semantics say every submitted future resolves
        srv = _server()
        sched = ContinuousScheduler(srv, batch_window_s=0.002)
        sched.prewarm([(3, 16, 16), (3, 24, 24)], batches=(1, 2, 4))
        rng = np.random.default_rng(0)
        futs = [sched.submit(rng.normal(size=shape).astype(np.float32))
                for _ in range(8)
                for shape in ((3, 16, 16), (3, 24, 24))]
        sched.close()  # drain=True: must not strand any future
        assert all(f.done() for f in futs)
        for f in futs:
            out = f.result(timeout=0)
            assert all(np.isfinite(v).all() for v in out.values())
        srv.close()

    def test_resize_thrash_races_inflight_groups(self):
        # the worker pool is retargeted continuously while groups are
        # being dispatched; nothing may be lost or computed wrong
        import threading
        srv = _server()
        sched = ContinuousScheduler(srv, batch_window_s=0.002)
        sched.prewarm([(3, 16, 16)], batches=(1, 2, 4))
        stop = threading.Event()

        def thrash():
            n = 0
            while not stop.is_set():
                srv.resize_workers(1 + n % 4)
                n += 1
                time.sleep(0.001)

        t = threading.Thread(target=thrash, name="resize-thrash")
        t.start()
        try:
            x = np.random.default_rng(1).normal(size=(3, 16, 16)) \
                .astype(np.float32)
            healthy = srv.infer(x)
            futs = [sched.submit(x) for _ in range(24)]
            for f in futs:
                out = f.result(timeout=60)
                for nid in healthy:
                    np.testing.assert_allclose(out[nid], healthy[nid],
                                               rtol=1e-5, atol=1e-6)
        finally:
            stop.set()
            t.join(timeout=10)
        sched.close()
        srv.close()

    def test_worker_death_during_close_still_drains(self):
        # a group requeued by a dying worker after close() was called
        # must still be served by the drain, not stranded
        from repro.reliability import FaultInjector, parse_fault_plan
        srv = _server(fault_injector=FaultInjector(
            parse_fault_plan("worker:raise@0+1")))
        sched = ContinuousScheduler(srv, batch_window_s=0.05)
        sched.prewarm([(3, 16, 16)])
        x = np.zeros((3, 16, 16), np.float32)
        fut = sched.submit(x)   # sits in the window when close() lands
        sched.close()
        out = fut.result(timeout=0)
        assert all(np.isfinite(v).all() for v in out.values())
        assert sched.stats()["worker_deaths"] == 1
        srv.close()
