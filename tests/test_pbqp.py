"""Unit + property tests for the PBQP solver (the paper's core engine)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal install: property tests skip, units run
    from _hypothesis_fallback import given, settings, st

from repro.core import pbqp
from repro.core.pbqp import PBQP, Infeasible, brute_force, solve


def _paper_example() -> PBQP:
    """The linear conv1-conv2-conv3 example of Figure 2 of the paper.

    Three primitives A/B/C per node; edge costs model data layout
    transformations (0 on the diagonal = same layout).
    """
    pb = PBQP()
    pb.add_node("conv1", [10.0, 4.0, 8.0])   # A, B, C
    pb.add_node("conv2", [20.0, 12.0, 3.0])
    pb.add_node("conv3", [12.0, 5.0, 7.0])
    # large off-diagonal transition costs: switching layouts is expensive
    T = np.array([
        [0.0, 9.0, 30.0],
        [9.0, 0.0, 30.0],
        [30.0, 30.0, 0.0],
    ])
    pb.add_edge("conv1", "conv2", T)
    pb.add_edge("conv2", "conv3", T)
    return pb


class TestBasics:
    def test_single_node(self):
        pb = PBQP()
        pb.add_node("a", [3.0, 1.0, 2.0])
        sol = solve(pb)
        assert sol.cost == 1.0
        assert sol.assignment == {"a": 1}
        assert sol.optimal

    def test_paper_figure2(self):
        pb = _paper_example()
        sol = solve(pb)
        bf = brute_force(pb)
        assert sol.cost == pytest.approx(bf.cost)
        # The paper's point: conv2's huge win with C drags conv1/conv3 to
        # co-adapt; naive per-node minima (B, C, B) cost 4+3+5+60 = 72,
        # the optimum is strictly cheaper.
        naive = pb.evaluate({"conv1": 1, "conv2": 2, "conv3": 1})
        assert sol.cost < naive

    def test_infeasible(self):
        pb = PBQP()
        pb.add_node("a", [1.0, 2.0])
        pb.add_node("b", [1.0, 2.0])
        pb.add_edge("a", "b", np.full((2, 2), np.inf))
        with pytest.raises(Infeasible):
            solve(pb)

    def test_infinite_edges_route_around(self):
        # a--b--c chain; a=0 forces b=1 (a0-b0 illegal), then b=1 makes
        # c's best become index 0 despite c preferring 1 locally.
        pb = PBQP()
        pb.add_node("a", [0.0, 100.0])
        pb.add_node("b", [5.0, 6.0])
        pb.add_node("c", [10.0, 0.0])
        pb.add_edge("a", "b", np.array([[np.inf, 0.0], [0.0, 0.0]]))
        pb.add_edge("b", "c", np.array([[0.0, 0.0], [0.0, np.inf]]))
        sol = solve(pb)
        assert sol.assignment == {"a": 0, "b": 1, "c": 0}
        assert sol.cost == pytest.approx(0 + 6 + 10)

    def test_parallel_edges_sum(self):
        pb = PBQP()
        pb.add_node("a", [0.0, 0.0])
        pb.add_node("b", [0.0, 0.0])
        M = np.array([[1.0, 2.0], [3.0, 4.0]])
        pb.add_edge("a", "b", M)
        pb.add_edge("b", "a", M.T)  # same edge again, reversed orientation
        sol = solve(pb)
        assert sol.cost == pytest.approx(2.0)

    def test_self_loop_folds_to_diagonal(self):
        pb = PBQP()
        pb.add_node("a", [0.0, 0.0])
        pb.add_edge("a", "a", np.array([[5.0, 99.0], [99.0, 1.0]]))
        sol = solve(pb)
        assert sol.cost == pytest.approx(1.0)
        assert sol.assignment["a"] == 1

    def test_edge_unknown_node_rejected(self):
        pb = PBQP()
        pb.add_node("a", [0.0, 0.0])
        with pytest.raises(ValueError, match="unknown node"):
            pb.add_edge("a", "ghost", np.zeros((2, 2)))
        with pytest.raises(ValueError, match="unknown node"):
            pb.add_edge("ghost", "a", np.zeros((2, 2)))
        # the self-loop path used to KeyError instead of this ValueError
        with pytest.raises(ValueError, match="unknown node"):
            pb.add_edge("ghost", "ghost", np.zeros((2, 2)))

    def test_self_loop_shape_validated(self):
        pb = PBQP()
        pb.add_node("a", [0.0, 0.0])
        with pytest.raises(ValueError, match="incompatible"):
            pb.add_edge("a", "a", np.zeros((3, 3)))
        with pytest.raises(ValueError, match="incompatible"):
            pb.add_edge("a", "a", np.zeros((2, 3)))

    def test_fully_infeasible_degree3_raises(self):
        """Regression: a fully-infeasible instance whose nodes all have
        degree >= 3 enters branch-and-bound with every branch infinite;
        the fallback must leave a *total* assignment behind and raise
        Infeasible (never KeyError out of pb.evaluate)."""
        def build():
            pb = PBQP()
            for i in range(4):
                pb.add_node(i, [1.0, 2.0])
            for i in range(4):
                for j in range(i + 1, 4):
                    pb.add_edge(i, j, np.full((2, 2), np.inf))
            return pb

        with pytest.raises(Infeasible):
            solve(build(), exact=True)
        # warm-started path: the (infinite-cost) warm assignment must
        # disable the bound and still end in Infeasible
        with pytest.raises(Infeasible):
            pbqp.solve_warm(build(), {i: 0 for i in range(4)}, exact=True)
        # branch node with an all-infinite cost vector, feasible-looking
        # edges: same contract
        pb = build()
        pb.add_node("u", [np.inf, np.inf])
        for i in range(4):
            pb.add_edge("u", i, np.zeros((2, 2)))
        with pytest.raises(Infeasible):
            solve(pb, exact=True)

    def test_dag_diamond(self):
        """Inception-style diamond (Figure 3): split + join."""
        pb = PBQP()
        for n in ["pre", "b1", "b2", "post"]:
            pb.add_node(n, [1.0, 1.0, 1.0])
        T = np.where(np.eye(3), 0.0, 50.0)
        pb.add_edge("pre", "b1", T)
        pb.add_edge("pre", "b2", T)
        pb.add_edge("b1", "post", T)
        pb.add_edge("b2", "post", T)
        sol = solve(pb)
        # all four nodes must agree on one layout
        vals = set(sol.assignment.values())
        assert len(vals) == 1
        assert sol.cost == pytest.approx(4.0)


# ----------------------------------------------------------------------
# random instances vs brute force
# ----------------------------------------------------------------------
def _random_instance(draw) -> PBQP:
    n = draw(st.integers(2, 6))
    pb = PBQP()
    doms = []
    for i in range(n):
        k = draw(st.integers(1, 4))
        doms.append(k)
        costs = [draw(st.floats(0, 100)) for _ in range(k)]
        pb.add_node(i, costs)
    # random edge set
    for i in range(n):
        for j in range(i + 1, n):
            if draw(st.booleans()):
                M = np.array(
                    [[draw(st.sampled_from([0.0, 1.0, 5.0, 25.0, np.inf]))
                      for _ in range(doms[j])] for _ in range(doms[i])]
                )
                pb.add_edge(i, j, M)
    return pb


@st.composite
def pbqp_instances(draw):
    return _random_instance(draw)


class TestAgainstBruteForce:
    @settings(max_examples=150, deadline=None)
    @given(pbqp_instances())
    def test_exact_matches_brute_force(self, pb):
        try:
            bf = brute_force(pb)
        except Infeasible:
            with pytest.raises(Infeasible):
                solve(pb, exact=True)
            return
        sol = solve(pb, exact=True)
        assert sol.optimal
        assert sol.cost == pytest.approx(bf.cost)
        # the reported assignment must actually achieve the reported cost
        assert pb.evaluate(sol.assignment) == pytest.approx(sol.cost)

    @settings(max_examples=80, deadline=None)
    @given(pbqp_instances())
    def test_heuristic_is_feasible_and_bounded_below_by_opt(self, pb):
        try:
            bf = brute_force(pb)
        except Infeasible:
            return  # heuristic may or may not detect; exact path covers it
        try:
            sol = solve(pb, exact=False)
        except Infeasible:
            return  # RN may paint itself into an illegal corner; acceptable
        assert sol.cost >= bf.cost - 1e-9
        assert pb.evaluate(sol.assignment) == pytest.approx(sol.cost)


class TestScale:
    def test_long_chain_exact_and_fast(self):
        """VGG-like deep chains reduce entirely via RI — O(n)."""
        rng = np.random.default_rng(0)
        pb = PBQP()
        n, k = 200, 8
        for i in range(n):
            pb.add_node(i, rng.uniform(1, 100, size=k))
        for i in range(n - 1):
            pb.add_edge(i, i + 1, rng.uniform(0, 50, size=(k, k)))
        sol = solve(pb)
        assert sol.optimal
        assert sol.stats["RN"] == 0
        assert np.isfinite(sol.cost)

    def test_dense_core_exact_via_bb(self):
        """K5 with random costs needs branch-and-bound; must match BF."""
        rng = np.random.default_rng(1)
        pb = PBQP()
        n, k = 5, 3
        for i in range(n):
            pb.add_node(i, rng.uniform(1, 100, size=k))
        for i in range(n):
            for j in range(i + 1, n):
                pb.add_edge(i, j, rng.uniform(0, 50, size=(k, k)))
        sol = solve(pb, exact=True)
        bf = brute_force(pb)
        assert sol.cost == pytest.approx(bf.cost)
        assert sol.optimal

    def test_googlenet_shaped_graph(self):
        """Chain of inception-like diamonds (degree-3/4 joins)."""
        rng = np.random.default_rng(2)
        pb = PBQP()
        k = 6
        prev = "stem"
        pb.add_node(prev, rng.uniform(1, 100, size=k))
        T = lambda: rng.uniform(0, 30, size=(k, k)) * (1 - np.eye(k))
        for blk in range(9):
            branches = [f"i{blk}b{t}" for t in range(4)]
            join = f"i{blk}join"
            for b in branches:
                pb.add_node(b, rng.uniform(1, 100, size=k))
                pb.add_edge(prev, b, T())
            pb.add_node(join, rng.uniform(0, 1, size=k))
            for b in branches:
                pb.add_edge(b, join, T())
            prev = join
        sol = solve(pb, exact=True)
        assert np.isfinite(sol.cost)
        assert sol.optimal
