"""Equivalence tests for the §Perf hillclimb variants: every
optimization must be a pure performance choice (identical numerics)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.flash_attention.ref import attention_ref
from repro.models import (
    ModelRuntime, ShardingPlan, forward_train, init_params,
)
from repro.models.common import chunked_causal_attention

PLAN = ShardingPlan(mesh=None)


class TestChunkedCausalAttention:
    @pytest.mark.parametrize("t,chunk", [(1024, 256), (2048, 512)])
    def test_matches_dense_causal(self, t, chunk):
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.normal(size=(1, t, 4, 32)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, t, 2, 32)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, t, 2, 32)), jnp.float32)
        got = chunked_causal_attention(q, k, v, scale=32 ** -0.5,
                                       softcap=0.0, chunk=chunk)
        want = jnp.swapaxes(
            attention_ref(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                          jnp.swapaxes(v, 1, 2), causal=True), 1, 2)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)

    def test_model_level_equivalence(self):
        cfg = get_config("tinyllama-1.1b").scaled_down(
            n_layers=2, d_model=64, d_ff=128, vocab=256, n_heads=4,
            n_kv_heads=2, head_dim=16)
        params = init_params(cfg, jax.random.key(0), jnp.float32)
        rng = np.random.default_rng(1)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, 256, size=(1, 1024)), jnp.int32)}
        base = forward_train(cfg, params, batch, PLAN,
                             ModelRuntime(attn_impl="xla"))
        opt = forward_train(cfg, params, batch, PLAN,
                            ModelRuntime(attn_impl="xla_chunked"))
        np.testing.assert_allclose(np.asarray(opt), np.asarray(base),
                                   rtol=2e-3, atol=2e-3)


class TestRematPolicy:
    def test_dots_policy_same_grads(self):
        cfg = get_config("tinyllama-1.1b").scaled_down(
            n_layers=2, d_model=64, d_ff=128, vocab=256, n_heads=4,
            n_kv_heads=2, head_dim=16)
        params = init_params(cfg, jax.random.key(0), jnp.float32)
        rng = np.random.default_rng(2)
        batch = {"tokens": jnp.asarray(rng.integers(0, 256, (2, 32)),
                                       jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, 256, (2, 32)),
                                       jnp.int32)}
        from repro.models import loss_fn

        def grads(rt):
            return jax.grad(lambda p: loss_fn(cfg, p, batch, PLAN, rt))(
                params)

        g_none = grads(ModelRuntime(remat=False))
        g_full = grads(ModelRuntime(remat=True, remat_policy="full"))
        g_dots = grads(ModelRuntime(remat=True, remat_policy="dots"))
        for ga, gb in zip(jax.tree.leaves(g_none), jax.tree.leaves(g_full)):
            np.testing.assert_allclose(np.asarray(ga), np.asarray(gb),
                                       rtol=1e-4, atol=1e-5)
        for ga, gb in zip(jax.tree.leaves(g_none), jax.tree.leaves(g_dots)):
            np.testing.assert_allclose(np.asarray(ga), np.asarray(gb),
                                       rtol=1e-4, atol=1e-5)
