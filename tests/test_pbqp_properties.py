"""Property-test hardening pass over the solver stack.

Three invariant families, each stated once as a plain checker and driven
two ways — by hypothesis (random structured instances, shrinking on
failure) and by a seeded ``np.random`` smoke loop that runs even on
minimal installs where hypothesis is absent, so the invariants are never
completely untested:

1. **Exactness** — the reduction + branch-and-bound solver agrees with
   exhaustive enumeration on every instance small enough to enumerate
   (<= 6 nodes, <= 4 choices), including instances with infinite
   (illegal) entries and infeasible ones.
2. **Warm-start purity** — ``solve_warm`` is a pure acceleration: for
   ANY warm assignment (the previous optimum, a random one, garbage
   ids, or None) the returned cost is identical to a cold exact solve.
3. **Plan legality** — ``select_pbqp`` never emits an unrealizable
   plan: every edge whose endpooints disagree on layout carries a
   materialized conversion chain (or fused realization) in the result,
   and the reported cost is finite and optimal.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal install: property tests skip, units run
    from _hypothesis_fallback import given, settings, st

from repro.core.pbqp import PBQP, Infeasible, brute_force, solve, \
    solve_warm

# ----------------------------------------------------------------------
# instance generation (shared shape: hypothesis draws and np.random both
# produce <= 6 nodes x <= 4 choices with a 5-valued edge-cost alphabet)
# ----------------------------------------------------------------------
_EDGE_COSTS = (0.0, 1.0, 5.0, 25.0, np.inf)


def _build(doms, node_costs, edge_matrices) -> PBQP:
    pb = PBQP()
    for i, costs in enumerate(node_costs):
        pb.add_node(i, costs)
    for (i, j), M in edge_matrices.items():
        pb.add_edge(i, j, M)
    return pb


@st.composite
def pbqp_instances(draw):
    n = draw(st.integers(2, 6))
    doms = [draw(st.integers(1, 4)) for _ in range(n)]
    node_costs = [[draw(st.floats(0, 100)) for _ in range(k)]
                  for k in doms]
    edges = {}
    for i in range(n):
        for j in range(i + 1, n):
            if draw(st.booleans()):
                edges[(i, j)] = np.array(
                    [[draw(st.sampled_from(_EDGE_COSTS))
                      for _ in range(doms[j])] for _ in range(doms[i])])
    return _build(doms, node_costs, edges)


def random_pbqp(rng: np.random.Generator) -> PBQP:
    """Same distribution as :func:`pbqp_instances`, seeded numpy draw —
    the no-hypothesis smoke loop and failure reproduction both use it."""
    n = int(rng.integers(2, 7))
    doms = [int(rng.integers(1, 5)) for _ in range(n)]
    node_costs = [rng.uniform(0, 100, size=k) for k in doms]
    edges = {}
    for i in range(n):
        for j in range(i + 1, n):
            if rng.integers(2):
                edges[(i, j)] = rng.choice(
                    _EDGE_COSTS, size=(doms[i], doms[j]))
    return _build(doms, node_costs, edges)


# ----------------------------------------------------------------------
# the invariants, stated once
# ----------------------------------------------------------------------
def check_exact_matches_brute(pb: PBQP) -> None:
    try:
        bf = brute_force(pb)
    except Infeasible:
        with pytest.raises(Infeasible):
            solve(pb, exact=True)
        return
    sol = solve(pb, exact=True)
    assert sol.optimal
    assert sol.cost == pytest.approx(bf.cost)
    # the reported assignment must actually achieve the reported cost
    assert pb.evaluate(sol.assignment) == pytest.approx(sol.cost)


def check_warm_matches_cold(pb: PBQP, rng: np.random.Generator) -> None:
    """Every flavour of warm seed yields the cold-exact cost."""
    try:
        cold = solve(pb, exact=True)
    except Infeasible:
        for warm in (None, {u: 0 for u in pb._costs}):
            with pytest.raises(Infeasible):
                solve_warm(pb, warm, exact=True)
        return
    seeds = [
        None,                                        # no seed at all
        dict(cold.assignment),                       # the optimum itself
        {u: int(rng.integers(pb.domain(u)))          # a random legal one
         for u in pb._costs},
        {u: 999 for u in pb._costs},                 # out-of-range
        {"not-a-node": 0},                           # wrong node set
    ]
    for warm in seeds:
        ws = solve_warm(pb, warm, exact=True)
        assert ws.cost == pytest.approx(cold.cost), f"warm={warm}"
        assert pb.evaluate(ws.assignment) == pytest.approx(ws.cost)
    # the optimum as seed must be recognised as usable and distance 0
    exact_seed = solve_warm(pb, dict(cold.assignment), exact=True)
    assert exact_seed.stats["WARM"] == 1
    assert exact_seed.stats["WARM_DIST"] == 0


def check_selection_legal(shape, depth: int, width: int,
                          mesh_axes=None, batch: int = 1) -> None:
    """select_pbqp output is realizable: every layout-mismatched edge
    carries a conversion chain (or fused realization).  With
    ``mesh_axes`` the placement axis joins the domain: pipeline stage
    boundaries are exempt from the no-conversion-on-matching-layouts
    rule (they wire through logical CHW regardless of the endpoint
    layouts), stage assignments must be monotone, and sharded kinds
    must be ones the mesh offers."""
    from repro.core.costs import AnalyticCostModel
    from repro.core.selection import (Placement, placements_for,
                                      select_pbqp)
    from repro.serving.towers import conv_tower, uniform_stack

    if mesh_axes and "stage" in mesh_axes:
        # the stage axis only matters on a pipelineable net
        net = uniform_stack(shape, depth=depth)
    else:
        net = conv_tower(shape, depth=depth, width=width)
    if batch > 1:
        net = net.with_batch(batch)
    sel = select_pbqp(net, AnalyticCostModel(), exact=True,
                      mesh_axes=mesh_axes)
    assert sel.optimal
    assert np.isfinite(sel.predicted_cost)
    assert set(sel.choices) == set(net.order)
    offered = set(placements_for(net, mesh_axes))
    pl = {nid: Placement.parse(sel.choices[nid].placement)
          for nid in net.order}
    for nid in net.order:
        assert str(pl[nid]) in offered or pl[nid].kind != "pp", pl[nid]
        if pl[nid].kind != "pp":
            assert str(pl[nid]) in offered, pl[nid]
    for (src, dst) in net.edges():
        lo = sel.choices[src].l_out
        li = sel.choices[dst].l_in
        pu, pv = pl[src], pl[dst]
        # pipeline membership is all-or-nothing and stage-monotone
        assert (pu.kind == "pp") == (pv.kind == "pp")
        if pu.kind == "pp":
            assert pv.stage >= pu.stage, f"backward hop {src}->{dst}"
            if pv.stage != pu.stage:
                # stage boundary: wired through CHW; a conversion
                # chain, when present, must pass through it
                chain = sel.conversions.get((src, dst))
                if lo == "CHW" and li == "CHW":
                    assert chain is None or "CHW" in chain
                else:
                    assert chain is not None and "CHW" in chain, \
                        f"stage boundary {src}->{dst} not CHW-wired"
                continue
        if lo == li:
            assert (src, dst) not in sel.conversions
        else:
            assert (src, dst) in sel.conversions \
                or (src, dst) in sel.fusions, \
                f"unrealized layout break on {src}->{dst} ({lo}->{li})"
            chain = sel.conversions.get((src, dst))
            if chain is not None:
                assert len(chain) >= 1


# ----------------------------------------------------------------------
# hypothesis drivers
# ----------------------------------------------------------------------
class TestSolverProperties:
    @settings(max_examples=120, deadline=None)
    @given(pbqp_instances())
    def test_exact_matches_brute_force(self, pb):
        check_exact_matches_brute(pb)

    @settings(max_examples=60, deadline=None)
    @given(pbqp_instances(), st.integers(0, 2**31 - 1))
    def test_warm_start_cost_identical_to_cold(self, pb, seed):
        check_warm_matches_cold(pb, np.random.default_rng(seed))


#: placement domains the property sweep draws from — every mesh kind
#: the solver offers, plus the meshless baseline
_MESH_DRAWS = (None, {"data": 2}, {"data": 4}, {"data": 2, "model": 2},
               {"model": 4}, {"stage": 2}, {"stage": 3})


class TestSelectionProperties:
    @settings(max_examples=15, deadline=None)
    @given(st.integers(2, 8), st.integers(10, 28), st.integers(10, 28),
           st.integers(1, 4), st.integers(2, 8))
    def test_plans_legal_under_legalize(self, c, h, w, depth, width):
        check_selection_legal((c, h, w), depth, width)

    @settings(max_examples=15, deadline=None)
    @given(st.integers(2, 8), st.integers(10, 28), st.integers(10, 28),
           st.integers(1, 4), st.integers(2, 8),
           st.sampled_from(_MESH_DRAWS), st.sampled_from((1, 4, 8)))
    def test_plans_legal_with_placements(self, c, h, w, depth, width,
                                         axes, batch):
        check_selection_legal((c, h, w), depth, width,
                              mesh_axes=axes, batch=batch)


# ----------------------------------------------------------------------
# seeded smoke loop: the same checkers, no hypothesis required.  Keeps
# the invariants exercised on minimal installs (and makes any hypothesis
# failure trivially reproducible from its numpy seed).
# ----------------------------------------------------------------------
class TestSeededSmoke:
    def test_exact_and_warm_seeded(self):
        rng = np.random.default_rng(1234)
        for _ in range(40):
            pb = random_pbqp(rng)
            check_exact_matches_brute(pb)
        for _ in range(15):
            pb = random_pbqp(rng)
            check_warm_matches_cold(pb, rng)

    def test_selection_legal_seeded(self):
        rng = np.random.default_rng(99)
        for _ in range(4):
            check_selection_legal(
                (int(rng.integers(2, 9)), int(rng.integers(10, 29)),
                 int(rng.integers(10, 29))),
                depth=int(rng.integers(1, 5)),
                width=int(rng.integers(2, 9)))

    def test_selection_legal_with_placements_seeded(self):
        rng = np.random.default_rng(7)
        for _ in range(4):
            axes = _MESH_DRAWS[int(rng.integers(len(_MESH_DRAWS)))]
            check_selection_legal(
                (int(rng.integers(2, 9)), int(rng.integers(10, 29)),
                 int(rng.integers(10, 29))),
                depth=int(rng.integers(1, 5)),
                width=int(rng.integers(2, 9)),
                mesh_axes=axes,
                batch=int(rng.choice((1, 4, 8))))
