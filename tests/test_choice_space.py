"""Unit tests for the unified choice-space PBQP builder."""
import numpy as np
import pytest

from repro.core import pbqp
from repro.core.choice_space import (
    ChoiceEdge, ChoiceNode, build_pbqp, drop_infinite,
)


class TestBuildPBQP:
    def test_matches_manual_construction(self):
        """build_pbqp must produce the same instance (same optimum) as
        hand-built PBQP with explicit matrices."""
        nodes = [
            ChoiceNode("a", ["a0", "a1"], [1.0, 5.0]),
            ChoiceNode("b", ["b0", "b1", "b2"], [2.0, 0.0, 9.0]),
        ]
        trans = lambda cu, cv: 10.0 if (cu, cv) == ("a0", "b1") else 0.5
        pb, domains = build_pbqp(nodes, [ChoiceEdge("a", "b", trans)])

        manual = pbqp.PBQP()
        manual.add_node("a", [1.0, 5.0])
        manual.add_node("b", [2.0, 0.0, 9.0])
        M = np.full((2, 3), 0.5)
        M[0, 1] = 10.0
        manual.add_edge("a", "b", M)

        got, want = pbqp.solve(pb), pbqp.solve(manual)
        assert got.cost == pytest.approx(want.cost)
        assert got.assignment == want.assignment
        assert domains["a"][got.assignment["a"]] in ("a0", "a1")

    def test_infinite_transitions_legal(self):
        """inf transitions encode illegal pairs; the solver routes
        around them."""
        nodes = [ChoiceNode("a", ["a0", "a1"], [0.0, 100.0]),
                 ChoiceNode("b", ["b0"], [0.0])]
        trans = lambda cu, cv: np.inf if cu == "a0" else 0.0
        pb, domains = build_pbqp(nodes, [ChoiceEdge("a", "b", trans)])
        sol = pbqp.solve(pb)
        assert domains["a"][sol.assignment["a"]] == "a1"
        assert sol.cost == pytest.approx(100.0)

    def test_node_validation(self):
        with pytest.raises(ValueError, match="choices"):
            ChoiceNode("a", ["x", "y"], [1.0])
        with pytest.raises(ValueError, match="empty"):
            ChoiceNode("a", [], [])

    def test_drop_infinite(self):
        entries = [("x", 1.0), ("y", np.inf), ("z", 2.0)]
        assert drop_infinite(entries) == [("x", 1.0), ("z", 2.0)]
        # an all-infinite domain is kept intact (solver reports
        # Infeasible instead of the builder crashing)
        only_inf = [("x", np.inf), ("y", np.inf)]
        assert drop_infinite(only_inf) == only_inf


class TestSharedBuildPath:
    """Both selection layers go through build_pbqp (the acceptance
    criterion of the unified-solver refactor)."""

    def test_selection_routes_through_builder(self, monkeypatch):
        import repro.core.choice_space as cs
        from repro.core.costs import AnalyticCostModel
        from repro.core.selection import select_pbqp
        from repro.serving.towers import conv_stack

        calls = []
        orig = cs.build_pbqp

        def spy(nodes, edges):
            calls.append(len(nodes))
            return orig(nodes, edges)

        monkeypatch.setattr("repro.core.selection.build_pbqp", spy)
        select_pbqp(conv_stack((4, 16, 16), depth=2, width=8),
                    AnalyticCostModel())
        assert calls, "select_pbqp did not use the shared builder"

    def test_sharding_routes_through_builder(self, monkeypatch):
        import repro.core.choice_space as cs
        from repro.configs import SHAPES, get_config
        from repro.core.sharding_select import select_rules

        calls = []
        orig = cs.build_pbqp

        def spy(nodes, edges):
            calls.append(len(nodes))
            return orig(nodes, edges)

        monkeypatch.setattr("repro.core.sharding_select.build_pbqp", spy)
        select_rules(get_config("mistral-nemo-12b"), SHAPES["train_4k"],
                     {"data": 16, "model": 16})
        assert calls, "select_rules did not use the shared builder"


class TestPlacementEdgePricing:
    def test_dp_to_rep_gather_prices_each_edges_own_bytes(self):
        """Every edge's dp->rep entry must charge the all-gather of THAT
        edge's tensor (regression: the transition closure once
        late-bound img_bytes, pricing every edge with the last edge's —
        typically much smaller — byte count)."""
        from repro.core import selection
        from repro.core.costs import AnalyticCostModel
        from repro.serving.towers import conv_stack

        nb, d = 8, 8
        net = conv_stack((4, 32, 32), depth=2, width=8).with_batch(nb)
        cm = AnalyticCostModel()
        pb, domains, _ = selection._build(net, cm,
                                          mesh_axes={"data": d})
        shapes = {net.nodes[s].out_shape for (s, _) in net.edges()}
        assert len(shapes) > 1, "fixture needs distinct edge tensors"
        for (src, dst) in net.edges():
            shape = net.nodes[src].out_shape
            want = cm.collective_cost(
                "all_gather", 4 * float(np.prod(shape)) * nb, d)
            M = pb.edge_cost(src, dst)
            du, dv = domains[src], domains[dst]
            i = next(k for k, c in enumerate(du) if c.placement == "dp")
            # rep/dp twins of the same consumer choice: their entry
            # difference is exactly the resharding gather (the layout
            # term is identical — both sharded-side, nb/D images)
            j_dp = next(k for k, c in enumerate(dv)
                        if c.placement == "dp")
            j_rep = next(k for k, c in enumerate(dv)
                         if c.placement == "rep"
                         and c.l_in == dv[j_dp].l_in
                         and (c.primitive.name if c.primitive else None)
                         == (dv[j_dp].primitive.name
                             if dv[j_dp].primitive else None))
            got = M[i, j_rep] - M[i, j_dp]
            assert got == pytest.approx(want, rel=1e-12), \
                f"edge {src}->{dst}: gather priced {got}, want {want}"


class TestMeshCompileValidation:
    def test_mesh_requires_batched_executable(self):
        from repro.core.costs import AnalyticCostModel
        from repro.core.plan import compile_plan
        from repro.core.selection import select_pbqp
        from repro.launch.mesh import make_cpu_mesh
        from repro.serving.towers import conv_stack

        net = conv_stack((4, 16, 16), depth=2, width=8)
        sel = select_pbqp(net, AnalyticCostModel())
        mesh = make_cpu_mesh(1, 1)
        with pytest.raises(ValueError, match="batch"):
            compile_plan(sel, net.init_params(0), batch=1, mesh=mesh)

    def test_placement_axis_needs_divisible_batch(self):
        """No dp choices are offered when the data axis cannot divide
        the batch — the plan falls back to all-rep."""
        from repro.core.costs import AnalyticCostModel
        from repro.core.selection import placements_for, select_pbqp
        from repro.serving.towers import conv_stack

        net = conv_stack((4, 16, 16), depth=2, width=8).with_batch(6)
        assert placements_for(net, {"data": 4}) == ["rep"]
        sel = select_pbqp(net, AnalyticCostModel(),
                          mesh_axes={"data": 4})
        assert all(c.placement == "rep" for c in sel.choices.values())
