"""Unit tests for the unified choice-space PBQP builder."""
import numpy as np
import pytest

from repro.core import pbqp
from repro.core.choice_space import (
    ChoiceEdge, ChoiceNode, build_pbqp, drop_infinite,
)


class TestBuildPBQP:
    def test_matches_manual_construction(self):
        """build_pbqp must produce the same instance (same optimum) as
        hand-built PBQP with explicit matrices."""
        nodes = [
            ChoiceNode("a", ["a0", "a1"], [1.0, 5.0]),
            ChoiceNode("b", ["b0", "b1", "b2"], [2.0, 0.0, 9.0]),
        ]
        trans = lambda cu, cv: 10.0 if (cu, cv) == ("a0", "b1") else 0.5
        pb, domains = build_pbqp(nodes, [ChoiceEdge("a", "b", trans)])

        manual = pbqp.PBQP()
        manual.add_node("a", [1.0, 5.0])
        manual.add_node("b", [2.0, 0.0, 9.0])
        M = np.full((2, 3), 0.5)
        M[0, 1] = 10.0
        manual.add_edge("a", "b", M)

        got, want = pbqp.solve(pb), pbqp.solve(manual)
        assert got.cost == pytest.approx(want.cost)
        assert got.assignment == want.assignment
        assert domains["a"][got.assignment["a"]] in ("a0", "a1")

    def test_infinite_transitions_legal(self):
        """inf transitions encode illegal pairs; the solver routes
        around them."""
        nodes = [ChoiceNode("a", ["a0", "a1"], [0.0, 100.0]),
                 ChoiceNode("b", ["b0"], [0.0])]
        trans = lambda cu, cv: np.inf if cu == "a0" else 0.0
        pb, domains = build_pbqp(nodes, [ChoiceEdge("a", "b", trans)])
        sol = pbqp.solve(pb)
        assert domains["a"][sol.assignment["a"]] == "a1"
        assert sol.cost == pytest.approx(100.0)

    def test_node_validation(self):
        with pytest.raises(ValueError, match="choices"):
            ChoiceNode("a", ["x", "y"], [1.0])
        with pytest.raises(ValueError, match="empty"):
            ChoiceNode("a", [], [])

    def test_drop_infinite(self):
        entries = [("x", 1.0), ("y", np.inf), ("z", 2.0)]
        assert drop_infinite(entries) == [("x", 1.0), ("z", 2.0)]
        # an all-infinite domain is kept intact (solver reports
        # Infeasible instead of the builder crashing)
        only_inf = [("x", np.inf), ("y", np.inf)]
        assert drop_infinite(only_inf) == only_inf


class TestSharedBuildPath:
    """Both selection layers go through build_pbqp (the acceptance
    criterion of the unified-solver refactor)."""

    def test_selection_routes_through_builder(self, monkeypatch):
        import repro.core.choice_space as cs
        from repro.core.costs import AnalyticCostModel
        from repro.core.selection import select_pbqp
        from repro.serving.towers import conv_stack

        calls = []
        orig = cs.build_pbqp

        def spy(nodes, edges):
            calls.append(len(nodes))
            return orig(nodes, edges)

        monkeypatch.setattr("repro.core.selection.build_pbqp", spy)
        select_pbqp(conv_stack((4, 16, 16), depth=2, width=8),
                    AnalyticCostModel())
        assert calls, "select_pbqp did not use the shared builder"

    def test_sharding_routes_through_builder(self, monkeypatch):
        import repro.core.choice_space as cs
        from repro.configs import SHAPES, get_config
        from repro.core.sharding_select import select_rules

        calls = []
        orig = cs.build_pbqp

        def spy(nodes, edges):
            calls.append(len(nodes))
            return orig(nodes, edges)

        monkeypatch.setattr("repro.core.sharding_select.build_pbqp", spy)
        select_rules(get_config("mistral-nemo-12b"), SHAPES["train_4k"],
                     {"data": 16, "model": 16})
        assert calls, "select_rules did not use the shared builder"


class TestPlacementEdgePricing:
    def test_dp_to_rep_gather_prices_each_edges_own_bytes(self):
        """Every edge's dp->rep entry must charge the all-gather of THAT
        edge's tensor (regression: the transition closure once
        late-bound img_bytes, pricing every edge with the last edge's —
        typically much smaller — byte count)."""
        from repro.core import selection
        from repro.core.costs import AnalyticCostModel
        from repro.serving.towers import conv_stack

        nb, d = 8, 8
        net = conv_stack((4, 32, 32), depth=2, width=8).with_batch(nb)
        cm = AnalyticCostModel()
        pb, domains, _ = selection._build(net, cm,
                                          mesh_axes={"data": d})
        shapes = {net.nodes[s].out_shape for (s, _) in net.edges()}
        assert len(shapes) > 1, "fixture needs distinct edge tensors"
        for (src, dst) in net.edges():
            shape = net.nodes[src].out_shape
            want = cm.collective_cost(
                "all_gather", 4 * float(np.prod(shape)) * nb, d)
            M = pb.edge_cost(src, dst)
            du, dv = domains[src], domains[dst]
            i = next(k for k, c in enumerate(du) if c.placement == "dp")
            # rep/dp twins of the same consumer choice: their entry
            # difference is exactly the resharding gather (the layout
            # term is identical — both sharded-side, nb/D images)
            j_dp = next(k for k, c in enumerate(dv)
                        if c.placement == "dp")
            j_rep = next(k for k, c in enumerate(dv)
                         if c.placement == "rep"
                         and c.l_in == dv[j_dp].l_in
                         and (c.primitive.name if c.primitive else None)
                         == (dv[j_dp].primitive.name
                             if dv[j_dp].primitive else None))
            got = M[i, j_rep] - M[i, j_dp]
            assert got == pytest.approx(want, rel=1e-12), \
                f"edge {src}->{dst}: gather priced {got}, want {want}"


class TestMeshCompileValidation:
    def test_mesh_requires_batched_executable(self):
        from repro.core.costs import AnalyticCostModel
        from repro.core.plan import compile_plan
        from repro.core.selection import select_pbqp
        from repro.launch.mesh import make_cpu_mesh
        from repro.serving.towers import conv_stack

        net = conv_stack((4, 16, 16), depth=2, width=8)
        sel = select_pbqp(net, AnalyticCostModel())
        mesh = make_cpu_mesh(1, 1)
        with pytest.raises(ValueError, match="batch"):
            compile_plan(sel, net.init_params(0), batch=1, mesh=mesh)

    def test_placement_axis_needs_divisible_batch(self):
        """No dp choices are offered when the data axis cannot divide
        the batch — the plan falls back to all-rep."""
        from repro.core.costs import AnalyticCostModel
        from repro.core.selection import placements_for, select_pbqp
        from repro.serving.towers import conv_stack

        net = conv_stack((4, 16, 16), depth=2, width=8).with_batch(6)
        assert placements_for(net, {"data": 4}) == ["rep"]
        sel = select_pbqp(net, AnalyticCostModel(),
                          mesh_axes={"data": 4})
        assert all(c.placement == "rep" for c in sel.choices.values())


class TestPlacement:
    """The structured placement domain: {rep, dp, tp, pp<stage>}."""

    def test_canonical_strings_and_structure(self):
        from repro.core.choice_space import Placement

        assert Placement("rep") == "rep"
        assert Placement("dp") == "dp"
        assert Placement("tp") == "tp"
        assert Placement("pp", 3) == "pp3"
        p = Placement("pp", 2)
        assert p.kind == "pp" and p.stage == 2
        assert Placement("dp").kind == "dp" and Placement("dp").stage == 0
        # str subclass: hashing and dict keys interop with plain strings
        assert hash(Placement("dp")) == hash("dp")
        assert {"dp": 1}[Placement("dp")] == 1

    def test_parse_round_trips(self):
        from repro.core.choice_space import Placement

        for s in ("rep", "dp", "tp", "pp0", "pp7"):
            p = Placement.parse(s)
            assert p == s
            assert Placement.parse(p) is p  # idempotent on instances
            assert Placement.parse(str(p)) == p

    def test_invalid_placements_raise(self):
        import pytest
        from repro.core.choice_space import Placement

        with pytest.raises(ValueError):
            Placement("mp")
        with pytest.raises(ValueError):
            Placement("pp", -1)
        for bad in ("", "pp", "ppx", "dp2", "sharded"):
            with pytest.raises(ValueError):
                Placement.parse(bad)


class TestWorldSizeOneCollectives:
    """Regression (satellite of the parallelism PR): every ring-model
    collective must cost exactly 0.0 for a 1-wide group — a tp/dp group
    of one device IS replication, and any nonzero (or divide-by-zero
    inf) term would make the solver and the 1-wide mesh disagree."""

    def test_all_collective_kinds_free_at_world_size_one(self):
        from repro.core.costs import (COLLECTIVE_KINDS, CPU_SPEC,
                                      collective_time)

        for kind in COLLECTIVE_KINDS:
            assert collective_time(CPU_SPEC, kind, 1e9, 1) == 0.0, kind

    def test_free_even_with_zero_link_bandwidth(self):
        """n=1 must short-circuit BEFORE touching link_bw: a host spec
        with no interconnect still prices 1-wide groups (and prices
        2-wide ones infinite, not NaN)."""
        import dataclasses

        from repro.core.costs import (COLLECTIVE_KINDS, CPU_SPEC,
                                      collective_time)

        spec = dataclasses.replace(CPU_SPEC, link_bw=0.0)
        for kind in COLLECTIVE_KINDS:
            assert collective_time(spec, kind, 1e6, 1) == 0.0, kind
            assert collective_time(spec, kind, 1e6, 2) == float("inf"), \
                kind

    def test_one_wide_mesh_prices_identically_to_meshless(self):
        from repro.core.costs import AnalyticCostModel
        from repro.core.selection import placements_for, select_pbqp
        from repro.serving.towers import conv_stack

        net = conv_stack((4, 16, 16), depth=2, width=8).with_batch(4)
        assert placements_for(net, {"data": 1, "model": 1}) == ["rep"]
        cm = AnalyticCostModel()
        sel1 = select_pbqp(net, cm, mesh_axes={"data": 1, "model": 1})
        sel0 = select_pbqp(net, cm)
        assert sel1.predicted_cost == sel0.predicted_cost
        assert all(c.placement == "rep" for c in sel1.choices.values())


class TestStageMonotonicity:
    """pp edge pricing: stages may only move forward along the chain,
    and pipeline membership is all-or-nothing."""

    def test_edge_collective_encodes_the_constraints(self):
        from repro.core.costs import AnalyticCostModel
        from repro.core.selection import Placement, PlacementPricing
        from repro.serving.towers import uniform_stack

        net = uniform_stack((8, 8, 8), depth=4).with_batch(8)
        cm = AnalyticCostModel()
        pm = PlacementPricing(net, cm, {"stage": 4})
        img = 4.0 * 8 * 8 * 8
        pp = lambda s: Placement("pp", s)
        # backward hops and pipeline islands are infinite
        assert pm.edge_collective(pp(2), pp(1), img) == float("inf")
        assert pm.edge_collective(pp(0), Placement("rep"), img) \
            == float("inf")
        assert pm.edge_collective(Placement("rep"), pp(0), img) \
            == float("inf")
        # same stage is free; forward hops price per boundary crossed
        assert pm.edge_collective(pp(1), pp(1), img) == 0.0
        one = pm.edge_collective(pp(0), pp(1), img)
        assert one > 0.0
        assert pm.edge_collective(pp(0), pp(3), img) \
            == pytest.approx(3 * one)

    def test_solved_pipeline_is_monotone_and_covers_the_mesh(self):
        from repro.core.costs import AnalyticCostModel
        from repro.core.selection import Placement, select_pbqp
        from repro.serving.towers import uniform_stack

        net = uniform_stack((8, 8, 8), depth=6).with_batch(8)
        sel = select_pbqp(net, AnalyticCostModel(),
                          mesh_axes={"stage": 4})
        pls = [Placement.parse(sel.choices[n].placement)
               for n in net.order]
        assert all(p.kind == "pp" for p in pls)
        stages = [p.stage for p in pls]
        assert stages == sorted(stages), "backward stage hop"
        assert stages[0] == 0 and stages[-1] == 3, \
            "pipeline must span the whole stage axis"

    def test_non_pipelineable_nets_get_no_pp(self):
        """conv_tower pools change shapes mid-chain: pp_chain rejects
        it, so the stage axis adds nothing to its domain."""
        from repro.core.selection import pp_chain, placements_for
        from repro.serving.towers import conv_tower, uniform_stack

        tower = conv_tower((4, 32, 32), depth=3, width=8).with_batch(8)
        assert pp_chain(tower) is None
        assert placements_for(tower, {"stage": 4}) == ["rep"]
        chain = uniform_stack((4, 8, 8), depth=2).with_batch(8)
        assert pp_chain(chain) == chain.order
