"""Numerical validation of the full primitive library against the
reference convolution oracle, across a sweep of scenarios covering every
family's supported envelope (K in {1,3,5,7,11}, strides, odd sizes,
blocked channels)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.layouts import LAYOUT_BY_NAME
from repro.core.primitives import (
    build_registry, convert_layout, primitives_for, registry,
)
from repro.core.scenario import Scenario, ref_conv

SCENARIOS = [
    Scenario(c=8, h=9, w=11, stride=1, k=3, m=16),
    Scenario(c=16, h=14, w=14, stride=1, k=3, m=8),
    Scenario(c=8, h=13, w=9, stride=2, k=3, m=8),
    Scenario(c=4, h=12, w=12, stride=1, k=5, m=8),
    Scenario(c=3, h=27, w=27, stride=2, k=5, m=16, pad=2),
    Scenario(c=8, h=10, w=10, stride=1, k=1, m=24, pad=0),
    Scenario(c=16, h=7, w=7, stride=1, k=1, m=8, pad=0),
    Scenario(c=3, h=31, w=31, stride=4, k=11, m=8, pad=0),  # AlexNet conv1
    Scenario(c=8, h=8, w=8, stride=1, k=7, m=8),
    Scenario(c=8, h=16, w=24, stride=1, k=3, m=32),  # non-square
]


def _run_primitive(p, scn, x, w, b):
    packed = p.prepare(scn, w, b)
    xin = LAYOUT_BY_NAME[p.l_in].to_memory(x)
    fn = jax.jit(p.make(scn))
    y = np.asarray(fn(jnp.asarray(xin), packed))
    return LAYOUT_BY_NAME[p.l_out].from_memory(y)


def _mk_data(scn, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=scn.in_shape_chw).astype(np.float32)
    w = (rng.normal(size=scn.weight_shape) * 0.1).astype(np.float32)
    b = rng.normal(size=(scn.m,)).astype(np.float32)
    return x, w, b


@pytest.mark.parametrize("scn", SCENARIOS, ids=lambda s: s.key())
def test_all_applicable_primitives_match_reference(scn):
    x, w, b = _mk_data(scn)
    want = ref_conv(x, w, b, scn.stride, scn.pad)
    prims = primitives_for(scn, exclude_tags=("tpu-only",))
    assert prims, f"no primitive supports {scn}"
    for p in prims:
        got = _run_primitive(p, scn, x, w, b)
        assert got.shape == want.shape, p.name
        np.testing.assert_allclose(
            got, want, rtol=2e-3, atol=2e-3,
            err_msg=f"{p.name} diverges on {scn.key()}")


def test_registry_size():
    """The paper's library has 'more than 70' primitives; ours too
    (67 CPU-profiled + the Pallas TPU kernels)."""
    assert len(registry()) >= 70


def test_every_family_present():
    fams = {p.family for p in registry()}
    assert {"direct", "im2", "kn2", "winograd", "fft"} <= fams


def test_every_primitive_reachable():
    """Every primitive must support at least one scenario in a broad
    envelope (no dead registry entries)."""
    envelope = [
        Scenario(c=8, h=16, w=16, stride=s, k=k, m=8)
        for s in (1, 2) for k in (1, 3, 5, 7)
    ]
    for p in registry():
        assert any(p.supports(s) for s in envelope), p.name


def test_kn2_rejects_stride():
    scn = Scenario(c=8, h=9, w=9, stride=2, k=3, m=8)
    assert not [p for p in primitives_for(scn) if p.family == "kn2"]


def test_winograd_rejects_k7():
    scn = Scenario(c=8, h=9, w=9, stride=1, k=7, m=8)
    assert not [p for p in primitives_for(scn) if p.family == "winograd"]


def test_blocked_needs_divisible_channels():
    scn = Scenario(c=6, h=9, w=9, stride=1, k=3, m=8)
    assert "direct_blocked_hwc8" not in [p.name for p in primitives_for(scn)]


def test_convert_layout_roundtrip():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(16, 6, 10)).astype(np.float32))
    for src in ["CHW", "HWC", "HCW", "HWC8"]:
        xm = convert_layout(x, "CHW", src)
        back = convert_layout(xm, src, "CHW")
        np.testing.assert_allclose(back, x, rtol=0, atol=0)


def test_convert_layout_matches_numpy_reference():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(8, 5, 7)).astype(np.float32)
    for name in ["HWC", "HCW", "HWC8"]:
        lay = LAYOUT_BY_NAME[name]
        got = np.asarray(convert_layout(jnp.asarray(x), "CHW", name))
        np.testing.assert_array_equal(got, lay.to_memory(x))
