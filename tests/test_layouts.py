"""Tests for layouts and the DT (data-layout transformation) graph."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # minimal install: property tests skip, units run
    from _hypothesis_fallback import given, settings, st

from repro.core.layouts import (
    ALL_LAYOUTS, CHW, HWC, HCW, HWC8, DTGraph, default_dt_graph,
)


class TestLayoutRoundTrip:
    @pytest.mark.parametrize("layout", ALL_LAYOUTS, ids=lambda l: l.name)
    def test_roundtrip(self, layout):
        rng = np.random.default_rng(0)
        x = rng.normal(size=(16, 5, 7)).astype(np.float32)
        mem = layout.to_memory(x)
        back = layout.from_memory(mem)
        np.testing.assert_array_equal(back, x)

    def test_hwc_memory_order(self):
        x = np.arange(2 * 3 * 4).reshape(2, 3, 4)
        mem = HWC.to_memory(x)
        assert mem.shape == (3, 4, 2)
        assert mem[1, 2, 0] == x[0, 1, 2]

    def test_blocked_layout_shape(self):
        x = np.zeros((16, 5, 7), np.float32)
        mem = HWC8.to_memory(x)
        assert mem.shape == (5, 7, 2, 8)

    def test_blocked_layout_requires_divisible(self):
        with pytest.raises(ValueError):
            HWC8.to_memory(np.zeros((10, 5, 7), np.float32))


class TestConvertLayoutRoundTrip:
    """The traced (jnp) layout converter: a->b->a is the identity for
    every ordered pair in ALL_LAYOUTS (blocked HWC8 included)."""

    PAIRS = [(a.name, b.name) for a in ALL_LAYOUTS for b in ALL_LAYOUTS]

    @pytest.mark.parametrize("src,dst", PAIRS,
                             ids=[f"{a}->{b}" for a, b in PAIRS])
    def test_roundtrip_identity(self, src, dst):
        from repro.core.layouts import LAYOUT_BY_NAME
        from repro.core.primitives import convert_layout
        rng = np.random.default_rng(1)
        x = rng.normal(size=(16, 5, 7)).astype(np.float32)  # C % 8 == 0
        mem = LAYOUT_BY_NAME[src].to_memory(x)
        back = convert_layout(convert_layout(mem, src, dst), dst, src)
        np.testing.assert_allclose(np.asarray(back), mem, rtol=0, atol=0)

    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 3), st.integers(1, 9), st.integers(1, 9))
    def test_roundtrip_identity_any_shape(self, cb, h, w):
        """Random shapes (C a multiple of 8 so HWC8 legs stay legal)."""
        from repro.core.primitives import convert_layout
        rng = np.random.default_rng(0)
        x = rng.normal(size=(8 * cb, h, w)).astype(np.float32)
        for a in ALL_LAYOUTS:
            for b in ALL_LAYOUTS:
                mem = a.to_memory(x)
                back = convert_layout(convert_layout(mem, a.name, b.name),
                                      b.name, a.name)
                np.testing.assert_allclose(np.asarray(back), mem,
                                           rtol=0, atol=0)

    def test_convert_matches_reference(self):
        """convert_layout(a->b) == from_memory/to_memory composition."""
        from repro.core.primitives import convert_layout
        rng = np.random.default_rng(2)
        x = rng.normal(size=(16, 6, 9)).astype(np.float32)
        for a in ALL_LAYOUTS:
            for b in ALL_LAYOUTS:
                got = convert_layout(a.to_memory(x), a.name, b.name)
                np.testing.assert_allclose(np.asarray(got), b.to_memory(x),
                                           rtol=0, atol=0)

    def test_hwc8_pallas_pad_crop(self):
        """The one-shot CHW<->HWC8 tiled kernels agree with the layout
        reference at spatial extents that force padding + cropping."""
        from repro.kernels.layout_transform import chw_to_hwc8, hwc8_to_chw
        rng = np.random.default_rng(3)
        x = rng.normal(size=(16, 11, 13)).astype(np.float32)  # odd H/W
        mem = np.asarray(chw_to_hwc8(x))
        np.testing.assert_allclose(mem, HWC8.to_memory(x), rtol=0, atol=0)
        np.testing.assert_allclose(np.asarray(hwc8_to_chw(mem)), x,
                                   rtol=0, atol=0)


class TestDTGraph:
    def test_direct_edge_cost(self):
        g = default_dt_graph()
        d, idx = g.cost_matrix((64, 32, 32))
        assert d[idx["CHW"], idx["HWC"]] > 0
        assert np.isfinite(d[idx["CHW"], idx["HWC"]])
        assert d[idx["CHW"], idx["CHW"]] == 0

    def test_chain_required(self):
        """HWC -> HCW has no direct routine: must chain via CHW."""
        g = default_dt_graph()
        chain = g.shortest_chain("HWC", "HCW", (64, 32, 32))
        assert chain is not None
        assert chain[0] == "HWC" and chain[-1] == "HCW"
        assert len(chain) >= 3  # at least one intermediate hop
        d, idx = g.cost_matrix((64, 32, 32))
        # chain cost equals sum of its direct hops
        hop_cost = sum(
            d[idx[a], idx[b]] for a, b in zip(chain, chain[1:]))
        assert d[idx["HWC"], idx["HCW"]] == pytest.approx(hop_cost)

    def test_unreachable_is_infinite(self):
        g = DTGraph()
        g.add_transform("A", "B", lambda s, d: 1.0)
        g.add_layout("Z")
        d, idx = g.cost_matrix((4, 4, 4))
        assert np.isinf(d[idx["A"], idx["Z"]])
        assert g.shortest_chain("A", "Z", (4, 4, 4)) is None

    def test_one_way_transform(self):
        g = DTGraph()
        g.add_transform("A", "B", lambda s, d: 1.0)
        d, idx = g.cost_matrix((4, 4, 4))
        assert np.isfinite(d[idx["A"], idx["B"]])
        assert np.isinf(d[idx["B"], idx["A"]])

    @settings(max_examples=30, deadline=None)
    @given(st.lists(
        st.tuples(st.integers(0, 5), st.integers(0, 5), st.floats(0.1, 10)),
        min_size=1, max_size=20))
    def test_apsp_triangle_inequality(self, edges):
        g = DTGraph()
        for i in range(6):
            g.add_layout(f"L{i}")
        for s, t, c in edges:
            if s != t:
                g.add_transform(f"L{s}", f"L{t}", lambda sh, dt, c=c: c)
        d, idx = g.cost_matrix((4, 4, 4))
        n = len(g.layouts)
        for i in range(n):
            for j in range(n):
                for k in range(n):
                    assert d[i, j] <= d[i, k] + d[k, j] + 1e-9

    @settings(max_examples=20, deadline=None)
    @given(st.lists(
        st.tuples(st.integers(0, 4), st.integers(0, 4), st.floats(0.1, 10)),
        min_size=1, max_size=12))
    def test_chain_realises_apsp_cost(self, edges):
        g = DTGraph()
        for i in range(5):
            g.add_layout(f"L{i}")
        costs = {}
        for s, t, c in edges:
            if s != t and (s, t) not in costs:
                costs[(s, t)] = c
                g.add_transform(f"L{s}", f"L{t}", lambda sh, dt, c=c: c)
        d, idx = g.cost_matrix((4, 4, 4))
        for i in range(5):
            for j in range(5):
                chain = g.shortest_chain(f"L{i}", f"L{j}", (4, 4, 4))
                if np.isinf(d[idx[f"L{i}"], idx[f"L{j}"]]):
                    assert chain is None or i == j
                else:
                    assert chain is not None
                    tot = sum(costs.get((int(a[1]), int(b[1])), np.inf)
                              for a, b in zip(chain, chain[1:]))
                    assert tot == pytest.approx(
                        d[idx[f"L{i}"], idx[f"L{j}"]], rel=1e-9)
