"""Tests for the distributed-level PBQP sharding selection."""
import json
import pathlib

import numpy as np
import pytest

from repro.configs import SHAPES, get_config
from repro.core.costs import TPU_V5E_SPEC, HardwareSpec
from repro.core.sharding_select import select_rules
from repro.models.sharding import MEGATRON_RULES, Rules

MESH_1POD = {"data": 16, "model": 16}
MESH_2POD = {"pod": 2, "data": 16, "model": 16}

#: pre-refactor behavior snapshot: select_rules assignments + costs for
#: every (arch, shape, mesh) cell, captured before the hardcoded
#: PEAK_FLOPS/HBM_BW/LINK_BW constants were replaced by HardwareSpec
#: and the PBQP build moved onto core.choice_space.  The refactor must
#: be cost-equivalent: identical picks, identical predicted comm.
GOLDEN = json.loads(
    (pathlib.Path(__file__).parent / "data" /
     "sharding_golden.json").read_text())
GOLDEN_MESHES = {"1pod": MESH_1POD, "2pod": MESH_2POD}


class TestFeasibility:
    def test_whisper_heads_not_divisible_falls_back(self):
        """20 heads % 16 != 0: the PBQP must not pick heads->model."""
        cfg = get_config("whisper-large-v3")
        rules, report = select_rules(cfg, SHAPES["train_4k"], MESH_1POD)
        assert report["assignment"]["attn"] != "attn:heads"
        assert rules.get("heads") != "model"

    def test_llava_56_heads_not_divisible(self):
        cfg = get_config("llava-next-34b")
        rules, report = select_rules(cfg, SHAPES["train_4k"], MESH_1POD)
        assert report["assignment"]["attn"] in ("attn:head_dim", "attn:rep")

    def test_dense_picks_megatron_tp(self):
        cfg = get_config("mistral-nemo-12b")
        rules, report = select_rules(cfg, SHAPES["train_4k"], MESH_1POD)
        assert report["assignment"]["attn"] == "attn:heads"
        assert report["assignment"]["ffn"] == "ffn:tp"
        assert rules.get("heads") == "model"

    def test_grok_8_experts_use_tp_within_expert(self):
        """8 experts % 16 != 0 -> EP infeasible; d_ff=32768 TP instead."""
        cfg = get_config("grok-1-314b")
        rules, report = select_rules(cfg, SHAPES["train_4k"], MESH_1POD)
        assert report["assignment"]["ffn"] == "ffn:tp"

    def test_kimi_384_experts_can_use_ep(self):
        cfg = get_config("kimi-k2-1t-a32b")
        rules, report = select_rules(cfg, SHAPES["train_4k"], MESH_1POD)
        assert report["assignment"]["ffn"] in ("ffn:ep", "ffn:tp")
        assert "ffn:ep" in report["domains"]["ffn"]

    def test_mamba_vocab_not_divisible(self):
        """50280 % 16 != 0: embed must not pick vocab sharding."""
        cfg = get_config("mamba2-2.7b")
        rules, report = select_rules(cfg, SHAPES["train_4k"], MESH_1POD)
        assert report["assignment"]["embed"] != "embed:vocab"

    def test_long_context_decode_shards_kv_seq(self):
        cfg = get_config("jamba-v0.1-52b")
        rules, report = select_rules(cfg, SHAPES["long_500k"], MESH_1POD)
        assert report["assignment"]["cache"] == "cache:seq"
        assert rules.get("kv_seq") is not None

    def test_batched_decode_prefers_batch_sharded_cache(self):
        cfg = get_config("mistral-nemo-12b")
        rules, report = select_rules(cfg, SHAPES["decode_32k"], MESH_1POD)
        assert report["assignment"]["cache"] == "cache:batch"


class TestSolverProperties:
    @pytest.mark.parametrize("arch", ["mistral-nemo-12b", "gemma2-9b",
                                      "kimi-k2-1t-a32b", "mamba2-2.7b",
                                      "whisper-large-v3"])
    @pytest.mark.parametrize("shape", ["train_4k", "decode_32k"])
    def test_always_solves_optimally(self, arch, shape):
        cfg = get_config(arch)
        rules, report = select_rules(cfg, SHAPES[shape], MESH_2POD)
        assert report["optimal"]
        assert np.isfinite(report["predicted_comm_s"])

    def test_multi_pod_batch_uses_pod_axis(self):
        cfg = get_config("tinyllama-1.1b")
        rules, _ = select_rules(cfg, SHAPES["train_4k"], MESH_2POD)
        batch_axes = rules.get("batch")
        assert "pod" in (batch_axes if isinstance(batch_axes, tuple)
                         else (batch_axes,))


class TestCostEquivalence:
    """The HardwareSpec + unified-builder refactor is cost-equivalent:
    every pick and every predicted comm time matches the pre-refactor
    golden snapshot (tests/data/sharding_golden.json)."""

    @pytest.mark.parametrize("key", sorted(GOLDEN))
    def test_matches_pre_refactor_golden(self, key):
        arch, sname, mname = key.split("|")
        _, rep = select_rules(get_config(arch), SHAPES[sname],
                              GOLDEN_MESHES[mname])
        want = GOLDEN[key]
        assert rep["assignment"] == want["assignment"]
        assert rep["predicted_comm_s"] == pytest.approx(
            want["predicted_comm_s"], rel=1e-12)

    def test_default_spec_is_tpu_v5e(self):
        cfg = get_config("mistral-nemo-12b")
        _, rep = select_rules(cfg, SHAPES["train_4k"], MESH_1POD)
        assert rep["spec"] == TPU_V5E_SPEC.name

    def test_no_fabric_spec_replicates_instead_of_crashing(self):
        """link_bw=0 (the HardwareSpec default) means no interconnect:
        every collective prices infinite and the solver must fall back
        to replication — never divide by zero."""
        cfg = get_config("mistral-nemo-12b")
        no_fabric = HardwareSpec(
            name="no-fabric", peak_flops=TPU_V5E_SPEC.peak_flops,
            mem_bw=TPU_V5E_SPEC.mem_bw)
        _, rep = select_rules(cfg, SHAPES["train_4k"], MESH_1POD,
                              spec=no_fabric)
        assert rep["optimal"]
        assert np.isfinite(rep["predicted_comm_s"])
        for group, choice in rep["assignment"].items():
            assert choice.endswith(":rep"), (group, choice)

    def test_spec_reprices_the_instance(self):
        """A slower fabric must raise (never lower) predicted comm and
        can legitimately change picks — the de Prado et al. point that
        selection must be re-priced per target platform."""
        cfg = get_config("mistral-nemo-12b")
        slow = HardwareSpec(
            name="tpu-slow-links", peak_flops=TPU_V5E_SPEC.peak_flops,
            mem_bw=TPU_V5E_SPEC.mem_bw,
            link_bw=TPU_V5E_SPEC.link_bw / 100,
            family_eff=TPU_V5E_SPEC.family_eff,
            family_setup=TPU_V5E_SPEC.family_setup)
        _, fast_rep = select_rules(cfg, SHAPES["train_4k"], MESH_1POD)
        _, slow_rep = select_rules(cfg, SHAPES["train_4k"], MESH_1POD,
                                   spec=slow)
        assert slow_rep["spec"] == "tpu-slow-links"
        assert slow_rep["predicted_comm_s"] > fast_rep["predicted_comm_s"]


class TestRules:
    def test_restrict_drops_missing_axes(self):
        r = Rules((("batch", ("pod", "data")), ("heads", "model")))
        r2 = r.restrict(["data", "model"])
        assert r2.get("batch") == "data"
        assert r2.get("heads") == "model"

    def test_spec_dedups_mesh_axes(self):
        r = Rules((("a", "model"), ("b", "model")))
        spec = r.spec(("a", "b"))
        # the same mesh axis may appear only once
        flat = [x for part in spec if part
                for x in ((part,) if isinstance(part, str) else part)]
        assert flat.count("model") == 1

    def test_feasible_divisibility(self):
        r = MEGATRON_RULES
        assert r.feasible(("d_model", "heads"), (512, 32),
                          {"data": 16, "model": 16, "pod": 1})
        assert not r.feasible(("d_model", "heads"), (512, 20),
                              {"data": 16, "model": 16, "pod": 1})
