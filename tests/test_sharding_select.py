"""Tests for the distributed-level PBQP sharding selection."""
import numpy as np
import pytest

from repro.configs import SHAPES, get_config
from repro.core.sharding_select import select_rules
from repro.models.sharding import MEGATRON_RULES, Rules

MESH_1POD = {"data": 16, "model": 16}
MESH_2POD = {"pod": 2, "data": 16, "model": 16}


class TestFeasibility:
    def test_whisper_heads_not_divisible_falls_back(self):
        """20 heads % 16 != 0: the PBQP must not pick heads->model."""
        cfg = get_config("whisper-large-v3")
        rules, report = select_rules(cfg, SHAPES["train_4k"], MESH_1POD)
        assert report["assignment"]["attn"] != "attn:heads"
        assert rules.get("heads") != "model"

    def test_llava_56_heads_not_divisible(self):
        cfg = get_config("llava-next-34b")
        rules, report = select_rules(cfg, SHAPES["train_4k"], MESH_1POD)
        assert report["assignment"]["attn"] in ("attn:head_dim", "attn:rep")

    def test_dense_picks_megatron_tp(self):
        cfg = get_config("mistral-nemo-12b")
        rules, report = select_rules(cfg, SHAPES["train_4k"], MESH_1POD)
        assert report["assignment"]["attn"] == "attn:heads"
        assert report["assignment"]["ffn"] == "ffn:tp"
        assert rules.get("heads") == "model"

    def test_grok_8_experts_use_tp_within_expert(self):
        """8 experts % 16 != 0 -> EP infeasible; d_ff=32768 TP instead."""
        cfg = get_config("grok-1-314b")
        rules, report = select_rules(cfg, SHAPES["train_4k"], MESH_1POD)
        assert report["assignment"]["ffn"] == "ffn:tp"

    def test_kimi_384_experts_can_use_ep(self):
        cfg = get_config("kimi-k2-1t-a32b")
        rules, report = select_rules(cfg, SHAPES["train_4k"], MESH_1POD)
        assert report["assignment"]["ffn"] in ("ffn:ep", "ffn:tp")
        assert "ffn:ep" in report["domains"]["ffn"]

    def test_mamba_vocab_not_divisible(self):
        """50280 % 16 != 0: embed must not pick vocab sharding."""
        cfg = get_config("mamba2-2.7b")
        rules, report = select_rules(cfg, SHAPES["train_4k"], MESH_1POD)
        assert report["assignment"]["embed"] != "embed:vocab"

    def test_long_context_decode_shards_kv_seq(self):
        cfg = get_config("jamba-v0.1-52b")
        rules, report = select_rules(cfg, SHAPES["long_500k"], MESH_1POD)
        assert report["assignment"]["cache"] == "cache:seq"
        assert rules.get("kv_seq") is not None

    def test_batched_decode_prefers_batch_sharded_cache(self):
        cfg = get_config("mistral-nemo-12b")
        rules, report = select_rules(cfg, SHAPES["decode_32k"], MESH_1POD)
        assert report["assignment"]["cache"] == "cache:batch"


class TestSolverProperties:
    @pytest.mark.parametrize("arch", ["mistral-nemo-12b", "gemma2-9b",
                                      "kimi-k2-1t-a32b", "mamba2-2.7b",
                                      "whisper-large-v3"])
    @pytest.mark.parametrize("shape", ["train_4k", "decode_32k"])
    def test_always_solves_optimally(self, arch, shape):
        cfg = get_config(arch)
        rules, report = select_rules(cfg, SHAPES[shape], MESH_2POD)
        assert report["optimal"]
        assert np.isfinite(report["predicted_comm_s"])

    def test_multi_pod_batch_uses_pod_axis(self):
        cfg = get_config("tinyllama-1.1b")
        rules, _ = select_rules(cfg, SHAPES["train_4k"], MESH_2POD)
        batch_axes = rules.get("batch")
        assert "pod" in (batch_axes if isinstance(batch_axes, tuple)
                         else (batch_axes,))


class TestRules:
    def test_restrict_drops_missing_axes(self):
        r = Rules((("batch", ("pod", "data")), ("heads", "model")))
        r2 = r.restrict(["data", "model"])
        assert r2.get("batch") == "data"
        assert r2.get("heads") == "model"

    def test_spec_dedups_mesh_axes(self):
        r = Rules((("a", "model"), ("b", "model")))
        spec = r.spec(("a", "b"))
        # the same mesh axis may appear only once
        flat = [x for part in spec if part
                for x in ((part,) if isinstance(part, str) else part)]
        assert flat.count("model") == 1

    def test_feasible_divisibility(self):
        r = MEGATRON_RULES
        assert r.feasible(("d_model", "heads"), (512, 32),
                          {"data": 16, "model": 16, "pod": 1})
        assert not r.feasible(("d_model", "heads"), (512, 20),
                              {"data": 16, "model": 16, "pod": 1})
