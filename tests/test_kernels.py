"""Per-kernel allclose validation against the pure-jnp oracles.

All Pallas kernels run in interpret mode on CPU (the kernel body
executes in Python); shapes and dtypes are swept per kernel.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.conv_direct import conv_direct, conv_direct_ref
from repro.kernels.conv_im2col import conv_im2col, conv_im2col_ref
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.layout_transform import (
    chw_to_hwc, chw_to_hwc_ref, hwc_to_chw, hwc_to_chw_ref,
)
from repro.kernels.matmul import matmul, matmul_ref
from repro.kernels.winograd_gemm import (
    bgemm_ref, conv_ref, conv_winograd, prepare_kernel,
    winograd_bgemm_pallas,
)

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


class TestMatmul:
    @pytest.mark.parametrize("m,k,n", [
        (128, 128, 128), (256, 384, 128), (64, 96, 32), (17, 33, 9),
        (1, 128, 128), (130, 257, 129),
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_shapes_dtypes(self, m, k, n, dtype):
        x = jnp.asarray(RNG.normal(size=(m, k)), dtype)
        y = jnp.asarray(RNG.normal(size=(k, n)), dtype)
        got = matmul(x, y)
        want = matmul_ref(x, y)
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            **_tol(dtype))

    def test_fused_bias_relu(self):
        x = jnp.asarray(RNG.normal(size=(64, 64)), jnp.float32)
        y = jnp.asarray(RNG.normal(size=(64, 48)), jnp.float32)
        b = jnp.asarray(RNG.normal(size=(48,)), jnp.float32)
        got = matmul(x, y, b, fuse_relu=True)
        want = matmul_ref(x, y, b, fuse_relu=True)
        np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)
        assert (np.asarray(got) >= 0).all()

    @pytest.mark.parametrize("bm,bn,bk", [(32, 32, 32), (64, 128, 32)])
    def test_block_shape_sweep(self, bm, bn, bk):
        x = jnp.asarray(RNG.normal(size=(128, 96)), jnp.float32)
        y = jnp.asarray(RNG.normal(size=(96, 160)), jnp.float32)
        got = matmul(x, y, bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(got, matmul_ref(x, y), rtol=2e-5,
                                   atol=2e-5)


class TestConvDirect:
    @pytest.mark.parametrize("h,w,c,m,k,stride,pad", [
        (14, 14, 16, 32, 3, 1, 1),
        (13, 9, 8, 16, 3, 2, 1),
        (27, 27, 3, 16, 5, 2, 2),
        (12, 12, 4, 8, 1, 1, 0),
        (10, 10, 8, 130, 3, 1, 1),   # m > block
    ])
    def test_shapes(self, h, w, c, m, k, stride, pad):
        x = jnp.asarray(RNG.normal(size=(h, w, c)), jnp.float32)
        wt = jnp.asarray(RNG.normal(size=(k, k, c, m)) * 0.1, jnp.float32)
        b = jnp.asarray(RNG.normal(size=(m,)), jnp.float32)
        got = conv_direct(x, wt, b, stride=stride, pad=pad)
        want = conv_direct_ref(x, wt, b, stride=stride, pad=pad)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_bf16(self):
        x = jnp.asarray(RNG.normal(size=(8, 8, 8)), jnp.bfloat16)
        wt = jnp.asarray(RNG.normal(size=(3, 3, 8, 16)) * 0.1, jnp.bfloat16)
        b = jnp.zeros((16,), jnp.bfloat16)
        got = conv_direct(x, wt, b, stride=1, pad=1)
        want = conv_direct_ref(x, wt, b, stride=1, pad=1)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=5e-2, atol=5e-2)


class TestConvIm2col:
    @pytest.mark.parametrize("h,w,c,m,k,stride,pad", [
        (14, 14, 16, 32, 3, 1, 1),
        (27, 27, 3, 16, 11, 4, 0),   # AlexNet conv1 shape family
        (9, 13, 8, 24, 5, 1, 2),
        (7, 7, 32, 8, 1, 1, 0),
    ])
    def test_shapes(self, h, w, c, m, k, stride, pad):
        x = jnp.asarray(RNG.normal(size=(c, h, w)), jnp.float32)
        wt = jnp.asarray(RNG.normal(size=(m, c, k, k)) * 0.1, jnp.float32)
        b = jnp.asarray(RNG.normal(size=(m,)), jnp.float32)
        got = conv_im2col(x, wt, b, stride=stride, pad=pad)
        want = conv_im2col_ref(x, wt, b, stride=stride, pad=pad)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


class TestWinogradGemm:
    @pytest.mark.parametrize("p,m,c,n", [(16, 32, 64, 128), (36, 8, 16, 49)])
    def test_bgemm(self, p, m, c, n):
        u = jnp.asarray(RNG.normal(size=(p, m, c)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(p, c, n)), jnp.float32)
        from repro.kernels.common import pad_to
        vp, _ = pad_to(v, 2, 128 if n >= 128 else n)
        up, _ = pad_to(u, 2, c)
        got = winograd_bgemm_pallas(up, vp, bn=vp.shape[2] // max(1, vp.shape[2] // 128) if vp.shape[2] % 128 else 128, bc=c)
        got = got[:, :, :n]
        np.testing.assert_allclose(got, bgemm_ref(u, v), rtol=2e-4,
                                   atol=2e-4)

    @pytest.mark.parametrize("m_", [2, 4])
    @pytest.mark.parametrize("h,w,c,m", [(14, 14, 8, 16), (9, 11, 4, 8)])
    def test_full_conv(self, m_, h, w, c, m):
        x = jnp.asarray(RNG.normal(size=(c, h, w)), jnp.float32)
        wt = jnp.asarray(RNG.normal(size=(m, c, 3, 3)) * 0.1, jnp.float32)
        b = jnp.asarray(RNG.normal(size=(m,)), jnp.float32)
        u = prepare_kernel(np.asarray(wt), m_)
        got = conv_winograd(x, u, b, m_=m_, k=3, pad=1)
        want = conv_ref(x, wt, b, pad=1)
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


class TestFlashAttention:
    @pytest.mark.parametrize("hq,hkv,lq,lk,d", [
        (4, 4, 128, 128, 32),
        (8, 2, 128, 256, 64),    # GQA group 4
        (4, 1, 64, 64, 32),      # MQA
        (2, 2, 100, 130, 16),    # unaligned seq -> padded + masked
    ])
    def test_plain(self, hq, hkv, lq, lk, d):
        q = jnp.asarray(RNG.normal(size=(1, hq, lq, d)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(1, hkv, lk, d)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(1, hkv, lk, d)), jnp.float32)
        got = flash_attention(q, k, v, bq=64, bk=64)
        want = attention_ref(q, k, v)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_causal(self):
        q = jnp.asarray(RNG.normal(size=(1, 2, 128, 32)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(1, 2, 128, 32)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(1, 2, 128, 32)), jnp.float32)
        got = flash_attention(q, k, v, causal=True, bq=32, bk=32)
        want = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_sliding_window_and_softcap(self):
        """gemma2-style: local window + logit soft-capping."""
        q = jnp.asarray(RNG.normal(size=(1, 2, 128, 16)), jnp.float32)
        k = jnp.asarray(RNG.normal(size=(1, 2, 128, 16)), jnp.float32)
        v = jnp.asarray(RNG.normal(size=(1, 2, 128, 16)), jnp.float32)
        got = flash_attention(q, k, v, causal=True, window=48,
                              softcap=30.0, bq=32, bk=32)
        want = attention_ref(q, k, v, causal=True, window=48, softcap=30.0)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_bf16(self):
        q = jnp.asarray(RNG.normal(size=(2, 2, 64, 32)), jnp.bfloat16)
        k = jnp.asarray(RNG.normal(size=(2, 2, 64, 32)), jnp.bfloat16)
        v = jnp.asarray(RNG.normal(size=(2, 2, 64, 32)), jnp.bfloat16)
        got = flash_attention(q, k, v, causal=True, bq=32, bk=32)
        want = attention_ref(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32),
                                   rtol=5e-2, atol=5e-2)


class TestLayoutTransform:
    @pytest.mark.parametrize("c,h,w", [(16, 32, 128), (3, 17, 50),
                                       (64, 8, 256)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_roundtrip_and_ref(self, c, h, w, dtype):
        x = jnp.asarray(RNG.normal(size=(c, h, w)), dtype)
        hwc = chw_to_hwc(x)
        np.testing.assert_array_equal(np.asarray(hwc),
                                      np.asarray(chw_to_hwc_ref(x)))
        back = hwc_to_chw(hwc)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(x))
        np.testing.assert_array_equal(np.asarray(hwc_to_chw(hwc)),
                                      np.asarray(hwc_to_chw_ref(hwc)))
