"""Observability acceptance tests (ISSUE: close the loop).

Pins down the three pillars end to end: the metrics registry is
exactly-once under a threaded hammer and its percentiles are correct;
trace spans nest correctly through the serving stack (including the
``infer_batch`` coalescing path and the cross-stack ``queue_wait``
region); and the drift detector flags a deliberately staled profile,
recalibrates ONLY the flagged entries, rotates every plan-cache key
through the content hash, and re-converges.
"""
import json
import math
import threading

import numpy as np
import pytest

from repro.core import plan as plan_mod
from repro.core.costs import AnalyticCostModel
from repro.core.plan import compile_plan
from repro.core.selection import select_pbqp
from repro.obs.metrics import Histogram, MetricsRegistry
from repro.obs.trace import NULL_SPAN, Tracer, configure, get_tracer
from repro.serving import BucketPolicy, PlanServer, conv_tower
from repro.serving.metrics import COUNT_FIELDS, TIME_FIELDS, ServingCounters
from repro.serving.towers import conv_stack

CM = AnalyticCostModel()
POLICY = BucketPolicy(min_hw=8, max_hw=64)

#: bounded primitive pool for the recalibration-loop tests — see
#: repro.obs.drift.RestrictedCostModel
ALLOWED = ("direct_lax_chw_chw_oihw", "direct_lax_hwc_hwc_hwio",
           "wino2d_f2x3_chw")


def _server(**kw):
    kw.setdefault("policy", POLICY)
    kw.setdefault("lru_capacity", 4)
    return PlanServer(lambda s: conv_tower(s, depth=2, width=8), CM, **kw)


@pytest.fixture
def sink():
    """Route the global tracer into a list for the test, then disable."""
    records = []
    configure(records, enabled=True)
    try:
        yield records
    finally:
        configure(enabled=False)


def _by_name(records, name):
    return [r for r in records if r["name"] == name]


# ---------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------
class TestMetricsRegistry:
    def test_counter_hammer_exact(self):
        reg = MetricsRegistry()
        c = reg.counter("hits")
        n_threads, per_thread = 8, 5000

        def worker():
            for _ in range(per_thread):
                c.add()

        ts = [threading.Thread(target=worker) for _ in range(n_threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert c.value == n_threads * per_thread
        assert isinstance(c.value, int)

    def test_histogram_hammer_exact(self):
        reg = MetricsRegistry()
        h = reg.histogram("lat")
        n_threads, per_thread = 8, 2000

        def worker(i):
            for j in range(per_thread):
                h.record(1e-6 * (i * per_thread + j + 1))

        ts = [threading.Thread(target=worker, args=(i,))
              for i in range(n_threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert h.count == n_threads * per_thread
        assert sum(h.counts) == h.count

    def test_percentiles(self):
        h = Histogram()
        for ms in range(1, 101):          # 1..100 ms, uniform
            h.record(ms * 1e-3)
        assert h.percentile(0) == pytest.approx(1e-3)
        assert h.percentile(100) == pytest.approx(0.1)
        # geometric buckets estimate within a factor of the bucket width
        assert h.percentile(50) == pytest.approx(0.05, rel=0.5)
        assert h.percentile(95) >= h.percentile(50)
        q = h.quantiles()
        assert set(q) == {"p50", "p95", "p99"}

    def test_percentile_single_sample_is_exact(self):
        h = Histogram()
        h.record(3.3e-3)
        for p in (0, 50, 99, 100):
            assert h.percentile(p) == pytest.approx(3.3e-3)

    def test_empty_histogram_nan(self):
        h = Histogram()
        assert math.isnan(h.percentile(50))
        assert h.snapshot()["count"] == 0

    def test_labels_key_distinct_metrics(self):
        reg = MetricsRegistry()
        reg.counter("x", phase="a").add(1)
        reg.counter("x", phase="b").add(2)
        snap = reg.snapshot()
        assert snap['x{phase="a"}'] == 1
        assert snap['x{phase="b"}'] == 2
        # same labels -> same underlying metric
        assert reg.counter("x", phase="a") is reg.counter("x", phase="a")

    def test_prometheus_text(self):
        reg = MetricsRegistry()
        reg.counter("requests").add(3)
        reg.histogram("lat_seconds", phase="execute").record(2e-3)
        text = reg.prometheus_text()
        assert "# TYPE requests counter" in text
        assert "requests 3" in text
        assert "# TYPE lat_seconds summary" in text
        assert 'lat_seconds{phase="execute",quantile="0.50"}' in text
        assert 'lat_seconds_count{phase="execute"} 1' in text
        assert text.endswith("\n")


# ---------------------------------------------------------------------
# serving counters on the registry
# ---------------------------------------------------------------------
class TestServingCounters:
    def test_snapshot_compat(self):
        c = ServingCounters()
        c.add(requests=2, solves=1, solve_s=0.5, plan_mem_hits=1,
              plan_misses=1)
        s = c.snapshot()
        for f in COUNT_FIELDS:
            assert isinstance(s[f], int), f
        for f in TIME_FIELDS:
            assert isinstance(s[f], float), f
        assert s["requests"] == 2 and s["solves"] == 1
        assert s["solve_s"] == pytest.approx(0.5)
        assert s["plan_hits"] == 1 and s["plan_hit_rate"] == 0.5
        assert c.requests == 2  # attribute reads still work

    def test_unknown_field_raises(self):
        with pytest.raises(AttributeError):
            ServingCounters().add(bogus=1)
        with pytest.raises(AttributeError):
            ServingCounters().bogus

    def test_threaded_hammer_no_lost_increments(self):
        c = ServingCounters()
        n_threads, per_thread = 8, 2000

        def worker():
            for _ in range(per_thread):
                c.add(requests=1, exec_hits=1, execute_s=1e-5)

        ts = [threading.Thread(target=worker) for _ in range(n_threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        total = n_threads * per_thread
        s = c.snapshot()
        assert s["requests"] == total
        assert s["exec_hits"] == total
        assert s["execute_s"] == pytest.approx(total * 1e-5)
        assert c.phase_quantiles()["execute"]["count"] == total

    def test_phase_quantiles_bucket_split(self):
        c = ServingCounters()
        c.add(execute_s=1e-3, _bucket="8x8x1")
        c.add(execute_s=2e-3, _bucket="16x16x1")
        q = c.phase_quantiles()
        assert q["execute"]["count"] == 2
        assert q["execute[bucket=8x8x1]"]["count"] == 1
        assert q["execute[bucket=16x16x1]"]["count"] == 1
        for v in q.values():
            assert {"count", "p50", "p95", "p99"} <= set(v)


# ---------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------
class TestTracer:
    def test_disabled_is_null(self):
        tr = Tracer()  # default: disabled, no sink
        with tr.span("x") as sp:
            assert sp is NULL_SPAN
            sp.set(ignored=1)
        tr.emit("y", 0.0, 1.0)

    def test_nesting_and_attrs(self):
        records = []
        tr = Tracer(records, enabled=True)
        with tr.span("outer", a=1) as outer:
            with tr.span("inner") as inner:
                inner.set(b=2)
            tr.emit("event", 1.0, 1.5, c=3)
        assert [r["name"] for r in records] == ["inner", "event", "outer"]
        inner_r, event_r, outer_r = records
        assert outer_r["parent"] is None and outer_r["a"] == 1
        assert inner_r["parent"] == outer_r["span"] and inner_r["b"] == 2
        assert event_r["parent"] == outer_r["span"]
        assert event_r["dur_s"] == pytest.approx(0.5)
        assert inner_r["trace"] == event_r["trace"] == outer_r["trace"]

    def test_sibling_spans_share_trace(self):
        records = []
        tr = Tracer(records, enabled=True)
        with tr.span("root"):
            with tr.span("a"):
                pass
            with tr.span("b"):
                pass
        a, b, root = records
        assert a["parent"] == b["parent"] == root["span"]

    def test_jsonl_file_sink(self, tmp_path):
        path = tmp_path / "trace.jsonl"
        tr = Tracer(path, enabled=True)
        with tr.span("x", k="v"):
            pass
        tr.flush()
        recs = [json.loads(line)
                for line in path.read_text().splitlines()]
        assert recs[0]["name"] == "x" and recs[0]["k"] == "v"
        assert {"trace", "span", "parent", "t0", "dur_s"} <= set(recs[0])


# ---------------------------------------------------------------------
# spans through the serving stack
# ---------------------------------------------------------------------
class TestServingSpans:
    def test_infer_cold_span_tree(self, sink):
        srv = _server()
        try:
            srv.infer(np.zeros((3, 12, 12), np.float32))
        finally:
            srv.close()
        names = {r["name"] for r in sink}
        assert {"infer", "plan", "pbqp.solve", "compile", "execute",
                "crop"} <= names
        infer = _by_name(sink, "infer")[0]
        plan = _by_name(sink, "plan")[0]
        solve = _by_name(sink, "pbqp.solve")[0]
        assert plan["parent"] == infer["span"]
        assert plan["source"] == "solve"
        assert solve["parent"] == plan["span"]
        assert {"nodes", "edges", "cost", "bb", "prunes"} <= set(solve)
        for name in ("execute", "crop", "compile"):
            r = _by_name(sink, name)[0]
            assert r["parent"] == infer["span"]
            assert r["trace"] == infer["trace"]

    def test_infer_warm_plan_source_mem(self, sink):
        srv = _server()
        try:
            x = np.zeros((3, 12, 12), np.float32)
            srv.infer(x)
            sink.clear()
            srv.infer(x)
        finally:
            srv.close()
        # hot bucket: no plan lookup at all (executable LRU hit), no
        # solve, no compile — just the request spans
        names = [r["name"] for r in sink]
        assert names.count("infer") == 1
        assert "pbqp.solve" not in names and "compile" not in names
        # evicting the executable but keeping the plan shows the
        # plan-tier memory hit
        srv2 = _server()
        try:
            srv2.plan_for(x.shape)
            sink.clear()
            srv2.infer(x)
            plan = _by_name(sink, "plan")[0]
            assert plan["source"] == "mem"
        finally:
            srv2.close()

    def test_coalesced_flush_span_tree(self, sink):
        srv = _server()
        try:
            imgs = [np.zeros((3, 12, 12), np.float32) for _ in range(3)]
            futs = [srv.enqueue(x) for x in imgs]
            served = srv.flush()
            assert served == 3
            for f in futs:
                assert f.result() is not None
        finally:
            srv.close()
        flush = _by_name(sink, "flush")[0]
        batch = _by_name(sink, "infer_batch")[0]
        waits = _by_name(sink, "queue_wait")
        execs = _by_name(sink, "execute")
        assert flush["requests"] == 3
        assert batch["parent"] == flush["span"]
        assert batch["requests"] == 3
        # 3 same-bucket images coalesce into ONE executable invocation
        assert batch["invocations"] == 1
        assert len(execs) == 1 and execs[0]["coalesced"] == 3
        assert execs[0]["parent"] == batch["span"]
        # queue_wait: opened in enqueue(), closed (and parented) in flush
        assert len(waits) == 3
        for w in waits:
            assert w["parent"] == flush["span"]
            assert w["trace"] == flush["trace"]
            assert w["dur_s"] >= 0.0

    def test_stats_phases_percentiles(self, sink):
        srv = _server()
        try:
            srv.infer(np.zeros((3, 12, 12), np.float32))
            s = srv.stats()
        finally:
            srv.close()
        phases = s["phases"]
        assert {"solve", "compile", "execute"} <= set(phases)
        for q in phases.values():
            assert q["count"] >= 1
            assert {"p50", "p95", "p99"} <= set(q)
        # per-bucket split for the executed bucket
        assert any(k.startswith("execute[bucket=") for k in phases)
        assert "serving_latency_seconds" in srv.metrics_text()


# ---------------------------------------------------------------------
# compile counter (satellite: thread-safe, registry-backed)
# ---------------------------------------------------------------------
class TestCompileCount:
    def test_concurrent_compiles_counted_exactly(self):
        net = conv_stack((3, 8, 8), depth=1, width=4)
        sel = select_pbqp(net, CM)
        params = net.init_params(0)
        before = plan_mod.compile_count()
        n_threads = 6

        def worker():
            compile_plan(sel, params, jit=False)

        ts = [threading.Thread(target=worker) for _ in range(n_threads)]
        for t in ts:
            t.start()
        for t in ts:
            t.join()
        assert plan_mod.compile_count() == before + n_threads


# ---------------------------------------------------------------------
# drift detection
# ---------------------------------------------------------------------
class TestInstrumentedNet:
    def test_outputs_identical_and_timings_complete(self):
        from repro.obs.drift import InstrumentedNet

        net = conv_stack((3, 12, 12), depth=2, width=8)
        sel = select_pbqp(net, CM)
        cnet = compile_plan(sel, net.init_params(0))
        inst = InstrumentedNet(cnet)
        x = np.random.default_rng(0).normal(
            size=(3, 12, 12)).astype(np.float32)
        ref = {k: np.asarray(v) for k, v in cnet(x).items()}
        outs, timings = inst(x)
        assert set(outs) == set(ref)
        for k in ref:
            np.testing.assert_allclose(outs[k], ref[k],
                                       rtol=1e-4, atol=1e-5)
        conv_ids = {n.id for n in net.conv_nodes()}
        assert conv_ids <= set(timings["node"])
        assert all(t > 0 for t in timings["node"].values())
        assert set(timings["edge"]) <= set(sel.conversions)
        assert timings["unmodeled_s"] >= 0.0


class TestDriftDetector:
    def _plan(self):
        net = conv_stack((3, 12, 12), depth=2, width=8)
        sel = select_pbqp(net, CM)
        return net, sel

    def _synthetic(self, pred, scale):
        return {"node": {nid: s * scale for nid, s in
                         pred["node"].items()},
                "edge": {}, "unmodeled_s": 0.0}

    def test_predictions_itemize_objective(self):
        from repro.obs.drift import plan_predictions

        net, sel = self._plan()
        pred = plan_predictions(sel, CM)
        total = sum(pred["node"].values()) + sum(pred["edge"].values())
        assert total == pytest.approx(sel.predicted_cost, rel=1e-6)

    def test_flags_only_drifted_entries(self):
        from repro.obs.drift import DriftDetector, plan_predictions

        net, sel = self._plan()
        pred = plan_predictions(sel, CM)
        det = DriftDetector(CM, threshold=2.0)
        det.observe(sel, self._synthetic(pred, 1.0))
        assert det.flagged() == []
        assert det.plan_within_threshold()

        det4 = DriftDetector(CM, threshold=2.0)
        det4.observe(sel, self._synthetic(pred, 4.0))
        flagged = {e.nid for e in det4.flagged()}
        assert flagged == {n.id for n in net.conv_nodes()}
        assert det4.plan_ratio() == pytest.approx(4.0, rel=1e-6)
        assert not det4.plan_within_threshold()
        rows = det4.report()
        assert rows[0]["flagged"] and rows[0]["ratio"] == \
            pytest.approx(4.0, rel=1e-6)
        rec = det4.recommendation()
        assert rec["recalibrate"] and set(rec["flagged"]) == flagged

    def test_ewma_converges_to_new_level(self):
        from repro.obs.drift import DriftDetector, plan_predictions

        net, sel = self._plan()
        pred = plan_predictions(sel, CM)
        det = DriftDetector(CM, alpha=0.5, threshold=2.0)
        det.observe(sel, self._synthetic(pred, 1.0))
        for _ in range(12):
            det.observe(sel, self._synthetic(pred, 4.0))
        assert all(e.ratio() == pytest.approx(4.0, rel=1e-2)
                   for e in det.entries.values())

    def test_recalibrate_writes_only_flagged(self):
        from repro.calibrate.profile import HardwareProfile
        from repro.obs.drift import DriftDetector, plan_predictions

        net, sel = self._plan()
        pred = plan_predictions(sel, CM)
        det = DriftDetector(CM, threshold=2.0)
        det.observe(sel, self._synthetic(pred, 4.0))
        profile = HardwareProfile.new()
        h0 = profile.content_hash()
        updated = det.recalibrate(profile)
        assert updated == [e.profile_key for e in det.flagged()
                           if e.profile_key]
        assert len(updated) == len({n.id for n in net.conv_nodes()})
        # the invalidation chain: new entries -> new content hash
        assert profile.content_hash() != h0
        for e in det.flagged():
            assert profile.get(e.profile_key) == pytest.approx(
                e.ewma_observed_s / max(e.per_image_div, 1))
        # nothing flagged -> nothing written, hash stable
        det_ok = DriftDetector(CM, threshold=2.0)
        det_ok.observe(sel, self._synthetic(pred, 1.0))
        h1 = profile.content_hash()
        assert det_ok.recalibrate(profile) == []
        assert profile.content_hash() == h1

    def test_rejects_mesh_plans_without_mesh_axes(self):
        from repro.obs.drift import plan_predictions

        net, sel = self._plan()
        # Choice is a frozen dataclass; forge a dp placement in place
        object.__setattr__(next(iter(sel.choices.values())),
                           "placement", "dp")
        with pytest.raises(ValueError, match="mesh-less"):
            plan_predictions(sel, CM)

    @pytest.mark.parametrize("mesh_axes", [
        {"data": 2, "model": 4}, {"stage": 4}])
    def test_itemizes_placed_plans_with_mesh_axes(self, mesh_axes):
        """With mesh_axes, a placement-solved plan itemizes into node
        compute + edge transforms + collective terms that sum back to
        the solver's objective exactly — the placement ledger comes
        from the same PlacementPricing the solver priced with."""
        from repro.obs.drift import plan_predictions
        from repro.serving.towers import bottleneck_tower, uniform_stack

        if "stage" in mesh_axes:
            net = uniform_stack((8, 8, 8), depth=6).with_batch(8)
        else:
            net = bottleneck_tower((4, 16, 16)).with_batch(8)
        sel = select_pbqp(net, CM, mesh_axes=mesh_axes)
        assert any(c.placement != "rep" for c in sel.choices.values())
        pred = plan_predictions(sel, CM, mesh_axes=mesh_axes)
        assert pred["collective"], "placed plan must itemize collectives"
        total = (sum(pred["node"].values()) +
                 sum(pred["edge"].values()) +
                 sum(pred["collective"].values()))
        assert total == pytest.approx(sel.predicted_cost, rel=1e-9)

    def test_report_rows_carry_placement(self):
        from repro.obs.drift import DriftDetector, plan_predictions

        net, sel = self._plan()
        det = DriftDetector(CM, threshold=2.0)
        det.observe(sel, self._synthetic(
            plan_predictions(sel, CM), 1.0))
        rows = det.report()
        assert rows
        assert all(r["placement"] == "rep" for r in rows
                   if r["kind"] == "node")


class TestDriftEndToEnd:
    """The full workflow: calibrate -> stale -> flag -> recalibrate."""

    def test_recalibration_loop_closes_the_loop(self):
        from repro.calibrate.model import CalibratedCostModel
        from repro.calibrate.profile import HardwareProfile
        from repro.obs.drift import (
            DriftDetector, InstrumentedNet, RestrictedCostModel,
            recalibration_loop,
        )
        from repro.serving.bucketing import bucket_key
        from repro.serving.plan_cache import plan_key

        shape = (3, 16, 16)
        net = conv_stack(shape, depth=2, width=8)
        params = net.init_params(0)
        x = np.random.default_rng(0).normal(size=shape).astype(np.float32)
        threshold, runs = 2.0, 2

        # calibrate from instrumented traffic to a fixed point
        profile = HardwareProfile.new()
        base = recalibration_loop(net, params, x, profile,
                                  allowed=ALLOWED, threshold=threshold,
                                  runs=runs)
        assert base["converged"]
        assert base["detector"].plan_within_threshold()

        # stale the profile: converged node entries 8x too fast — the
        # underpriced entries *attract* the next solve
        hash_before = profile.content_hash()
        perturbed = {}
        for e in base["detector"].entries.values():
            if e.kind != "node":
                continue
            old = profile.get(e.profile_key)
            profile.put(e.profile_key,
                        (old if old is not None else e.predicted_s) / 8.0)
            perturbed[e.nid] = e.profile_key
        assert profile.content_hash() != hash_before

        cost = RestrictedCostModel(CalibratedCostModel(profile), ALLOWED)
        sel = select_pbqp(net, cost)
        inst = InstrumentedNet(compile_plan(sel, params))
        det = DriftDetector(cost, threshold=threshold)
        for _ in range(runs):
            _, tm = inst(x)
            det.observe(sel, tm)
        flagged = det.flagged()
        # every perturbed node is flagged...
        assert set(perturbed) <= {e.nid for e in flagged}
        assert not det.plan_within_threshold()

        # ...and recalibration touches ONLY flagged entries
        hash_stale = profile.content_hash()
        updated = det.recalibrate(profile)
        assert set(updated) <= {e.profile_key for e in flagged}
        assert set(perturbed.values()) <= set(updated)

        # content hash rotation invalidates every cached plan key
        bkey = bucket_key(shape, 1)
        v_stale = CalibratedCostModel.__name__ + hash_stale
        v_fresh = CalibratedCostModel.__name__ + profile.content_hash()
        assert plan_key(net.fingerprint(), bkey, v_stale) != \
            plan_key(net.fingerprint(), bkey, v_fresh)

        # re-converge: the re-solved plan predicts within threshold
        post = recalibration_loop(net, params, x, profile,
                                  allowed=ALLOWED, threshold=threshold,
                                  runs=runs, max_rounds=4)
        assert post["converged"]
        assert post["detector"].plan_within_threshold()

    def test_calibrated_model_version_tracks_profile(self):
        from repro.calibrate.model import CalibratedCostModel
        from repro.calibrate.profile import HardwareProfile
        from repro.obs.drift import RestrictedCostModel

        profile = HardwareProfile.new()
        cm = CalibratedCostModel(profile)
        v0 = cm.version()
        profile.put("prim::direct_lax_chw_chw_oihw::whatever", 1e-3)
        assert CalibratedCostModel(profile).version() != v0
        r = RestrictedCostModel(CalibratedCostModel(profile), ALLOWED)
        assert "+allow=" in r.version()
