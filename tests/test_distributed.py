"""Multi-device tests: run in subprocesses with fake CPU devices so the
main pytest process keeps a single device (per the dry-run contract —
XLA_FLAGS must not leak globally)."""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr[-3000:]}"
    return proc.stdout


class TestShardedModel:
    def test_model_lowers_and_runs_on_4x2_mesh(self):
        out = run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs import get_config
            from repro.models import (init_params, loss_fn, ShardingPlan,
                                      MEGATRON_RULES, ModelRuntime)
            cfg = get_config('tinyllama-1.1b').scaled_down(
                n_layers=2, d_model=64, d_ff=128, vocab=512,
                n_heads=4, n_kv_heads=2, head_dim=16)
            from repro.launch.mesh import make_mesh_compat
            mesh = make_mesh_compat((4, 2), ('data', 'model'))
            rules = MEGATRON_RULES.restrict(mesh.axis_names)
            plan = ShardingPlan(mesh=mesh, rules=rules)
            params = init_params(cfg, jax.random.key(0), jnp.float32)
            rng = np.random.default_rng(0)
            batch = {'tokens': jnp.asarray(rng.integers(0, 512, (8, 16)),
                                           jnp.int32),
                     'labels': jnp.asarray(rng.integers(0, 512, (8, 16)),
                                           jnp.int32)}
            with mesh:
                loss = jax.jit(lambda p, b: loss_fn(cfg, p, b, plan,
                                                    ModelRuntime()))(
                    params, batch)
            assert jnp.isfinite(loss), loss
            # single-device reference must match the sharded result
            plan0 = ShardingPlan(mesh=None)
            loss0 = loss_fn(cfg, params, batch, plan0, ModelRuntime())
            assert abs(float(loss) - float(loss0)) < 1e-3, (loss, loss0)
            print('OK', float(loss))
        """)
        assert "OK" in out

    def test_sharded_matches_unsharded_moe(self):
        out = run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs import get_config
            from repro.models import (init_params, forward_train,
                                      ShardingPlan, MEGATRON_RULES,
                                      ModelRuntime)
            cfg = get_config('grok-1-314b').scaled_down(
                n_layers=2, d_model=64, d_ff=128, vocab=512,
                n_heads=4, n_kv_heads=2, head_dim=16, n_experts=4,
                top_k=2)
            from repro.launch.mesh import make_mesh_compat
            mesh = make_mesh_compat((2, 4), ('data', 'model'))
            plan = ShardingPlan(mesh=mesh,
                                rules=MEGATRON_RULES.restrict(
                                    mesh.axis_names))
            params = init_params(cfg, jax.random.key(1), jnp.float32)
            rng = np.random.default_rng(0)
            batch = {'tokens': jnp.asarray(rng.integers(0, 512, (4, 16)),
                                           jnp.int32)}
            with mesh:
                lg = jax.jit(lambda p, b: forward_train(
                    cfg, p, b, plan, ModelRuntime()))(params, batch)
            lg0 = forward_train(cfg, params, batch, ShardingPlan(None),
                                ModelRuntime())
            err = float(jnp.max(jnp.abs(lg - lg0)))
            assert err < 2e-2, err
            print('OK', err)
        """)
        assert "OK" in out


class TestPipelineParallel:
    def test_pipeline_matches_sequential(self):
        out = run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.runtime import pipeline_apply
            S, n_micro, mb, d = 4, 8, 2, 16
            from repro.launch.mesh import make_mesh_compat
            mesh = make_mesh_compat((S,), ('stage',))
            rng = np.random.default_rng(0)
            w = jnp.asarray(rng.normal(size=(S, d, d)) * 0.3, jnp.float32)
            x = jnp.asarray(rng.normal(size=(n_micro, mb, d)), jnp.float32)
            def stage_fn(params, xm):
                return jnp.tanh(xm @ params['w'])
            y = pipeline_apply(mesh, stage_fn, {'w': w}, x,
                               n_micro=n_micro, axis='stage')
            # sequential reference
            ref = x
            for s in range(S):
                ref = jnp.tanh(ref @ w[s])
            err = float(jnp.max(jnp.abs(y - ref)))
            assert err < 1e-5, err
            print('OK', err)
        """)
        assert "OK" in out


    def test_pipeline_ticks_formula(self):
        """Fill-drain schedule length: T = n_micro + S - 1."""
        from repro.runtime.pipeline_parallel import pipeline_ticks
        assert pipeline_ticks(1, 1) == 1
        assert pipeline_ticks(4, 8) == 11
        assert pipeline_ticks(2, 1) == 2
        with pytest.raises(ValueError):
            pipeline_ticks(0, 4)
        with pytest.raises(ValueError):
            pipeline_ticks(4, 0)

    def test_degenerate_single_stage(self):
        """S=1: the pipeline IS the stage function (one tick per
        microbatch, no boundary transfers)."""
        out = run_with_devices("""
            import jax.numpy as jnp, numpy as np
            from repro.runtime import pipeline_apply
            from repro.launch.mesh import make_mesh_compat
            mesh = make_mesh_compat((1,), ('stage',))
            rng = np.random.default_rng(0)
            w = jnp.asarray(rng.normal(size=(1, 8, 8)) * 0.3, jnp.float32)
            x = jnp.asarray(rng.normal(size=(4, 2, 8)), jnp.float32)
            y = pipeline_apply(mesh, lambda p, xm: jnp.tanh(xm @ p['w']),
                               {'w': w}, x, n_micro=4)
            ref = jnp.tanh(x @ w[0])
            err = float(jnp.max(jnp.abs(y - ref)))
            assert err < 1e-6, err
            print('OK', err)
        """, n_devices=1)
        assert "OK" in out

    def test_degenerate_single_microbatch(self):
        """n_micro=1: pure fill-drain bubble (T = S ticks), still
        correct."""
        out = run_with_devices("""
            import jax.numpy as jnp, numpy as np
            from repro.runtime import pipeline_apply
            from repro.launch.mesh import make_mesh_compat
            S = 4
            mesh = make_mesh_compat((S,), ('stage',))
            rng = np.random.default_rng(1)
            w = jnp.asarray(rng.normal(size=(S, 8, 8)) * 0.3, jnp.float32)
            x = jnp.asarray(rng.normal(size=(1, 3, 8)), jnp.float32)
            y = pipeline_apply(mesh, lambda p, xm: jnp.tanh(xm @ p['w']),
                               {'w': w}, x, n_micro=1)
            ref = x
            for s in range(S):
                ref = jnp.tanh(ref @ w[s])
            err = float(jnp.max(jnp.abs(y - ref)))
            assert err < 1e-5, err
            print('OK', err)
        """, n_devices=4)
        assert "OK" in out


class TestCompression:
    def test_quantized_psum_close_to_exact(self):
        out = run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            from repro.optim import compressed_psum_tree
            from repro.launch.mesh import make_mesh_compat
            mesh = make_mesh_compat((8,), ('pod',))
            rng = np.random.default_rng(0)
            g = jnp.asarray(rng.normal(size=(8, 64, 32)), jnp.float32)
            def f(gl):
                return compressed_psum_tree({'g': gl[0]}, 'pod')['g']
            out = shard_map(f, mesh=mesh, in_specs=P('pod'),
                            out_specs=P())(g)
            exact = jnp.mean(g, axis=0)
            rel = float(jnp.linalg.norm(out - exact) /
                        jnp.linalg.norm(exact))
            assert rel < 0.05, rel
            print('OK', rel)
        """)
        assert "OK" in out

    def test_quantize_roundtrip_unbiased(self):
        import jax
        import jax.numpy as jnp
        import numpy as np

        from repro.optim import dequantize_int8, quantize_int8
        x = jnp.asarray(np.random.default_rng(0).normal(size=(1000,)),
                        jnp.float32)
        deq = []
        for i in range(20):
            q, s = quantize_int8(x, jax.random.key(i))
            deq.append(np.asarray(dequantize_int8(q, s)))
        err = np.abs(np.mean(deq, axis=0) - np.asarray(x)).max()
        assert err < 0.02  # stochastic rounding averages out


class TestShardedPlan:
    """The unified choice-space pipeline: solve (placement axis) ->
    compile (mesh executables) -> serve, on an 8-fake-device CPU mesh.
    Acceptance: mesh-sharded outputs identical to the unsharded plan."""

    def test_sharded_tower_matches_unsharded(self):
        out = run_with_devices("""
            import numpy as np
            from repro.core.costs import AnalyticCostModel
            from repro.core.plan import compile_plan
            from repro.core.selection import select_pbqp
            from repro.launch.mesh import make_mesh_compat
            from repro.serving.towers import conv_stack, conv_tower

            mesh = make_mesh_compat((8,), ('data',))
            cm = AnalyticCostModel()
            rng = np.random.default_rng(0)
            modes = set()
            for builder in (conv_stack, conv_tower):
                net = builder((4, 32, 32), depth=3, width=8).with_batch(8)
                sel = select_pbqp(net, cm, mesh_axes={'data': 8})
                assert sel.optimal
                assert any(c.placement == 'dp'
                           for c in sel.choices.values()), 'no dp chosen'
                sel0 = select_pbqp(net, cm)
                assert all(c.placement == 'rep'
                           for c in sel0.choices.values())
                params = net.init_params(0)
                x = rng.normal(size=(8, 4, 32, 32)).astype(np.float32)
                cn = compile_plan(sel, params, batch=8, mesh=mesh)
                cn0 = compile_plan(sel0, params, batch=8)
                modes.add(cn.mesh_mode)
                out, out0 = cn(x), cn0(x)
                assert set(out) == set(out0)
                for k in out:
                    np.testing.assert_allclose(
                        np.asarray(out[k]), np.asarray(out0[k]),
                        rtol=2e-3, atol=2e-3)
            # both executable modes exercised: the all-dp shard_map
            # fast path and the mixed-placement GSPMD path
            assert modes == {'shard_map', 'gspmd'}, modes
            print('OK', sorted(modes))
        """)
        assert "OK" in out

    def test_mesh_plan_server_matches_plain(self):
        out = run_with_devices("""
            import numpy as np
            from repro.core.costs import AnalyticCostModel
            from repro.launch.mesh import make_mesh_compat
            from repro.serving import BucketPolicy, PlanServer, conv_stack

            mesh = make_mesh_compat((8,), ('data',))
            policy = BucketPolicy(min_hw=8, max_hw=64)
            build = lambda s: conv_stack(s, depth=2, width=8)
            rng = np.random.default_rng(0)
            stream = [rng.normal(size=(
                4, int(rng.integers(12, 17)), int(rng.integers(12, 17))
                )).astype(np.float32) for _ in range(16)]
            srv_m = PlanServer(build, AnalyticCostModel(), policy=policy,
                               mesh=mesh)
            srv_0 = PlanServer(build, AnalyticCostModel(), policy=policy)
            # the mesh topology is part of every cache key
            assert srv_m.cost_version != srv_0.cost_version
            out_m = srv_m.infer_batch(stream)
            out_0 = srv_0.infer_batch(stream)
            for i in range(len(stream)):
                assert set(out_m[i]) == set(out_0[i])
                for k in out_m[i]:
                    assert out_m[i][k].shape == out_0[i][k].shape
                    np.testing.assert_allclose(out_m[i][k], out_0[i][k],
                                               rtol=2e-3, atol=2e-3)
            s = srv_m.stats()
            assert s['mesh_compiles'] >= 1, s
            # single-image latency path stays mesh-free but must agree
            one_m = srv_m.infer(stream[0])
            one_0 = srv_0.infer(stream[0])
            for k in one_m:
                np.testing.assert_allclose(one_m[k], one_0[k],
                                           rtol=2e-3, atol=2e-3)
            srv_m.close(); srv_0.close()
            print('OK', int(s['mesh_compiles']))
        """)
        assert "OK" in out

    def test_mesh_plan_roundtrips_through_disk_cache(self):
        out = run_with_devices("""
            import numpy as np, tempfile
            from repro.core.costs import AnalyticCostModel
            from repro.launch.mesh import make_mesh_compat
            from repro.serving import BucketPolicy, PlanServer, conv_stack

            mesh = make_mesh_compat((8,), ('data',))
            policy = BucketPolicy(min_hw=8, max_hw=64)
            build = lambda s: conv_stack(s, depth=2, width=8)
            xs = [np.ones((4, 16, 16), np.float32)] * 8
            with tempfile.TemporaryDirectory() as d:
                srv = PlanServer(build, AnalyticCostModel(),
                                 policy=policy, mesh=mesh, cache_dir=d)
                out1 = srv.infer_batch(xs)
                assert srv.stats()['solves'] == 1
                srv.close()
                # new server, same dir: placements come back from disk
                srv2 = PlanServer(build, AnalyticCostModel(),
                                  policy=policy, mesh=mesh, cache_dir=d)
                out2 = srv2.infer_batch(xs)
                s = srv2.stats()
                assert s['solves'] == 0 and s['plan_disk_hits'] == 1, s
                assert s['mesh_compiles'] >= 1, s
                for k in out1[0]:
                    np.testing.assert_allclose(out1[0][k], out2[0][k],
                                               rtol=2e-3, atol=2e-3)
                srv2.close()
            print('OK')
        """)
        assert "OK" in out


class TestFullParallelismPlans:
    """The enlarged placement space {rep, dp, tp, pp} end to end:
    solve -> compile -> execute, verified output-identical to the
    unsharded executable (docs/distributed.md)."""

    def test_mixed_tp_dp_plan_matches_unsharded(self):
        out = run_with_devices("""
            import numpy as np
            from repro.core.costs import AnalyticCostModel
            from repro.core.plan import compile_plan
            from repro.core.selection import Placement, select_pbqp
            from repro.launch.mesh import make_mesh_compat
            from repro.serving.towers import bottleneck_tower

            mesh = make_mesh_compat((2, 4), ('data', 'model'))
            net = bottleneck_tower((4, 16, 16)).with_batch(8)
            cm = AnalyticCostModel()
            sel = select_pbqp(net, cm,
                              mesh_axes={'data': 2, 'model': 4})
            kinds = {Placement.parse(c.placement).kind
                     for c in sel.choices.values()}
            # the fat 1x1-spatial body is weight-bandwidth bound: the
            # solver must shard its weights (tp), not its batch
            assert 'tp' in kinds and 'dp' in kinds, kinds
            params = net.init_params(0)
            x = np.random.default_rng(0).normal(
                size=(8, 4, 16, 16)).astype(np.float32)
            cn = compile_plan(sel, params, batch=8, mesh=mesh)
            assert cn.mesh_mode == 'tp_shard_map', cn.mesh_mode
            assert cn.tp_nodes > 0 and cn.dp_nodes > 0
            cn0 = compile_plan(select_pbqp(net, cm), params, batch=8)
            out, out0 = cn(x), cn0(x)
            assert set(out) == set(out0)
            for k in out:
                np.testing.assert_allclose(
                    np.asarray(out[k]), np.asarray(out0[k]),
                    rtol=2e-3, atol=2e-3)
            print('OK', sorted(kinds))
        """)
        assert "OK" in out

    def test_solved_pipeline_matches_unsharded(self):
        out = run_with_devices("""
            import numpy as np
            from repro.core.costs import AnalyticCostModel
            from repro.core.plan import compile_plan
            from repro.core.selection import Placement, select_pbqp
            from repro.launch.mesh import make_mesh_compat
            from repro.serving.towers import uniform_stack

            mesh = make_mesh_compat((4,), ('stage',))
            net = uniform_stack((8, 8, 8), depth=6).with_batch(8)
            cm = AnalyticCostModel()
            sel = select_pbqp(net, cm, mesh_axes={'stage': 4})
            assert all(Placement.parse(c.placement).kind == 'pp'
                       for c in sel.choices.values())
            params = net.init_params(0)
            x = np.random.default_rng(0).normal(
                size=(8, 8, 8, 8)).astype(np.float32)
            cn = compile_plan(sel, params, batch=8, mesh=mesh)
            assert cn.mesh_mode == 'pipeline', cn.mesh_mode
            assert cn.pp_nodes == len(net.order)
            cn0 = compile_plan(select_pbqp(net, cm), params, batch=8)
            out, out0 = cn(x), cn0(x)
            for k in out:
                np.testing.assert_allclose(
                    np.asarray(out[k]), np.asarray(out0[k]),
                    rtol=2e-3, atol=2e-3)
            print('OK')
        """, n_devices=4)
        assert "OK" in out

    def test_pure_dp_flattens_over_both_batch_axes(self):
        """A pure-dp plan prices and runs identically on an (8,) and a
        (2, 4) mesh — dp shards over ALL non-stage axes."""
        out = run_with_devices("""
            import numpy as np
            from repro.core.costs import AnalyticCostModel
            from repro.core.plan import compile_plan
            from repro.core.selection import select_pbqp
            from repro.launch.mesh import make_mesh_compat
            from repro.serving.towers import conv_stack

            cm = AnalyticCostModel()
            net = conv_stack((4, 32, 32), depth=3, width=8).with_batch(8)
            sel_24 = select_pbqp(net, cm,
                                 mesh_axes={'data': 2, 'model': 4})
            sel_8 = select_pbqp(net, cm, mesh_axes={'data': 8})
            assert sel_24.predicted_cost == sel_8.predicted_cost
            assert all(c.placement == 'dp'
                       for c in sel_24.choices.values())
            mesh = make_mesh_compat((2, 4), ('data', 'model'))
            params = net.init_params(0)
            x = np.random.default_rng(0).normal(
                size=(8, 4, 32, 32)).astype(np.float32)
            cn = compile_plan(sel_24, params, batch=8, mesh=mesh)
            assert cn.mesh_mode == 'shard_map', cn.mesh_mode
            cn0 = compile_plan(select_pbqp(net, cm), params, batch=8)
            out, out0 = cn(x), cn0(x)
            for k in out:
                np.testing.assert_allclose(
                    np.asarray(out[k]), np.asarray(out0[k]),
                    rtol=2e-3, atol=2e-3)
            print('OK')
        """)
        assert "OK" in out


class TestForceHostDevices:
    """XLA_FLAGS mangling for fake-device meshes (single home:
    launch/mesh.py::force_host_devices — serve CLI and the sharding
    benchmark both route through it)."""

    def test_appends_when_absent(self, monkeypatch):
        from repro.launch.mesh import force_host_devices
        monkeypatch.setenv("XLA_FLAGS", "--some_other_flag")
        force_host_devices(8)
        assert os.environ["XLA_FLAGS"] == \
            "--some_other_flag --xla_force_host_platform_device_count=8"

    def test_replaces_smaller_keeps_larger(self, monkeypatch):
        from repro.launch.mesh import force_host_devices
        monkeypatch.setenv(
            "XLA_FLAGS", "--xla_force_host_platform_device_count=4")
        force_host_devices(8)  # a 4-device flag cannot carry an 8-mesh
        assert "--xla_force_host_platform_device_count=8" in \
            os.environ["XLA_FLAGS"]
        force_host_devices(2)  # but a larger pre-set count is kept
        assert "--xla_force_host_platform_device_count=8" in \
            os.environ["XLA_FLAGS"]


class TestElastic:
    def test_remesh_on_device_change(self):
        out = run_with_devices("""
            import jax
            from repro.runtime import ElasticController
            from repro.models.sharding import MEGATRON_RULES

            def make_mesh(n):
                from repro.launch.mesh import make_mesh_compat
                d = max(n // 2, 1)
                return make_mesh_compat((d, 2 if n >= 2 else 1),
                                        ('data', 'model'))

            ec = ElasticController(make_mesh, lambda shape: MEGATRON_RULES)
            mesh1, plan1, ch1 = ec.current()
            assert not ch1
            mesh2, plan2, ch2 = ec.current()
            assert not ch2 and ec.generation == 0
            print('OK', mesh1.devices.shape)
        """)
        assert "OK" in out
