"""Multi-device tests: run in subprocesses with fake CPU devices so the
main pytest process keeps a single device (per the dry-run contract —
XLA_FLAGS must not leak globally)."""
import json
import os
import pathlib
import subprocess
import sys
import textwrap

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = str(REPO / "src")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=timeout,
                          env=env)
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr[-3000:]}"
    return proc.stdout


class TestShardedModel:
    def test_model_lowers_and_runs_on_4x2_mesh(self):
        out = run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs import get_config
            from repro.models import (init_params, loss_fn, ShardingPlan,
                                      MEGATRON_RULES, ModelRuntime)
            cfg = get_config('tinyllama-1.1b').scaled_down(
                n_layers=2, d_model=64, d_ff=128, vocab=512,
                n_heads=4, n_kv_heads=2, head_dim=16)
            from repro.launch.mesh import make_mesh_compat
            mesh = make_mesh_compat((4, 2), ('data', 'model'))
            rules = MEGATRON_RULES.restrict(mesh.axis_names)
            plan = ShardingPlan(mesh=mesh, rules=rules)
            params = init_params(cfg, jax.random.key(0), jnp.float32)
            rng = np.random.default_rng(0)
            batch = {'tokens': jnp.asarray(rng.integers(0, 512, (8, 16)),
                                           jnp.int32),
                     'labels': jnp.asarray(rng.integers(0, 512, (8, 16)),
                                           jnp.int32)}
            with mesh:
                loss = jax.jit(lambda p, b: loss_fn(cfg, p, b, plan,
                                                    ModelRuntime()))(
                    params, batch)
            assert jnp.isfinite(loss), loss
            # single-device reference must match the sharded result
            plan0 = ShardingPlan(mesh=None)
            loss0 = loss_fn(cfg, params, batch, plan0, ModelRuntime())
            assert abs(float(loss) - float(loss0)) < 1e-3, (loss, loss0)
            print('OK', float(loss))
        """)
        assert "OK" in out

    def test_sharded_matches_unsharded_moe(self):
        out = run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.configs import get_config
            from repro.models import (init_params, forward_train,
                                      ShardingPlan, MEGATRON_RULES,
                                      ModelRuntime)
            cfg = get_config('grok-1-314b').scaled_down(
                n_layers=2, d_model=64, d_ff=128, vocab=512,
                n_heads=4, n_kv_heads=2, head_dim=16, n_experts=4,
                top_k=2)
            from repro.launch.mesh import make_mesh_compat
            mesh = make_mesh_compat((2, 4), ('data', 'model'))
            plan = ShardingPlan(mesh=mesh,
                                rules=MEGATRON_RULES.restrict(
                                    mesh.axis_names))
            params = init_params(cfg, jax.random.key(1), jnp.float32)
            rng = np.random.default_rng(0)
            batch = {'tokens': jnp.asarray(rng.integers(0, 512, (4, 16)),
                                           jnp.int32)}
            with mesh:
                lg = jax.jit(lambda p, b: forward_train(
                    cfg, p, b, plan, ModelRuntime()))(params, batch)
            lg0 = forward_train(cfg, params, batch, ShardingPlan(None),
                                ModelRuntime())
            err = float(jnp.max(jnp.abs(lg - lg0)))
            assert err < 2e-2, err
            print('OK', err)
        """)
        assert "OK" in out


class TestPipelineParallel:
    def test_pipeline_matches_sequential(self):
        out = run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from repro.runtime import pipeline_apply
            S, n_micro, mb, d = 4, 8, 2, 16
            from repro.launch.mesh import make_mesh_compat
            mesh = make_mesh_compat((S,), ('stage',))
            rng = np.random.default_rng(0)
            w = jnp.asarray(rng.normal(size=(S, d, d)) * 0.3, jnp.float32)
            x = jnp.asarray(rng.normal(size=(n_micro, mb, d)), jnp.float32)
            def stage_fn(params, xm):
                return jnp.tanh(xm @ params['w'])
            y = pipeline_apply(mesh, stage_fn, {'w': w}, x,
                               n_micro=n_micro, axis='stage')
            # sequential reference
            ref = x
            for s in range(S):
                ref = jnp.tanh(ref @ w[s])
            err = float(jnp.max(jnp.abs(y - ref)))
            assert err < 1e-5, err
            print('OK', err)
        """)
        assert "OK" in out


class TestCompression:
    def test_quantized_psum_close_to_exact(self):
        out = run_with_devices("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            from repro.optim import compressed_psum_tree
            from repro.launch.mesh import make_mesh_compat
            mesh = make_mesh_compat((8,), ('pod',))
            rng = np.random.default_rng(0)
            g = jnp.asarray(rng.normal(size=(8, 64, 32)), jnp.float32)
            def f(gl):
                return compressed_psum_tree({'g': gl[0]}, 'pod')['g']
            out = shard_map(f, mesh=mesh, in_specs=P('pod'),
                            out_specs=P())(g)
            exact = jnp.mean(g, axis=0)
            rel = float(jnp.linalg.norm(out - exact) /
                        jnp.linalg.norm(exact))
            assert rel < 0.05, rel
            print('OK', rel)
        """)
        assert "OK" in out

    def test_quantize_roundtrip_unbiased(self):
        import jax
        import jax.numpy as jnp
        import numpy as np

        from repro.optim import dequantize_int8, quantize_int8
        x = jnp.asarray(np.random.default_rng(0).normal(size=(1000,)),
                        jnp.float32)
        deq = []
        for i in range(20):
            q, s = quantize_int8(x, jax.random.key(i))
            deq.append(np.asarray(dequantize_int8(q, s)))
        err = np.abs(np.mean(deq, axis=0) - np.asarray(x)).max()
        assert err < 0.02  # stochastic rounding averages out


class TestElastic:
    def test_remesh_on_device_change(self):
        out = run_with_devices("""
            import jax
            from repro.runtime import ElasticController
            from repro.models.sharding import MEGATRON_RULES

            def make_mesh(n):
                from repro.launch.mesh import make_mesh_compat
                d = max(n // 2, 1)
                return make_mesh_compat((d, 2 if n >= 2 else 1),
                                        ('data', 'model'))

            ec = ElasticController(make_mesh, lambda shape: MEGATRON_RULES)
            mesh1, plan1, ch1 = ec.current()
            assert not ch1
            mesh2, plan2, ch2 = ec.current()
            assert not ch2 and ec.generation == 0
            print('OK', mesh1.devices.shape)
        """)
        assert "OK" in out
