"""Pytest bootstrap: make `repro` (src layout) and `benchmarks`
importable regardless of how pytest is invoked.

NOTE: deliberately does NOT set XLA_FLAGS — tests must see the real
single-device CPU; only repro/launch/dryrun.py forces 512 devices.
"""
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent
for p in (str(ROOT), str(ROOT / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)
