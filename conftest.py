"""Pytest bootstrap: make `repro` (src layout) and `benchmarks`
importable regardless of how pytest is invoked, and fail any test that
leaks a live scheduler/server thread.

NOTE: deliberately does NOT set XLA_FLAGS — tests must see the real
single-device CPU; only repro/launch/dryrun.py forces 512 devices.
"""
import pathlib
import sys
import threading
import time

import pytest

ROOT = pathlib.Path(__file__).resolve().parent
for p in (str(ROOT), str(ROOT / "src")):
    if p not in sys.path:
        sys.path.insert(0, p)

#: thread-name prefixes owned by the serving stack — every one of these
#: is joined by a close()/shutdown() the owning test must call
_OWNED_THREAD_PREFIXES = ("sched-dispatch", "sched-batch", "planserver")


def _serving_threads():
    return [t for t in threading.enumerate()
            if t.is_alive()
            and t.name.startswith(_OWNED_THREAD_PREFIXES)]


@pytest.fixture(autouse=True)
def _no_thread_leaks():
    """Fail the test (not just warn) if it leaks a dispatcher/worker.

    A leaked ContinuousScheduler dispatcher or PlanServer pool thread
    outlives its test, pins its executables in memory, and can deadlock
    a later test's close() — exactly the resource bug the reliability
    layer exists to prevent in production, so the suite holds itself to
    the same standard.  Grace period: pool threads finish their current
    item after shutdown() returns only when close() was actually
    called, so a short poll-join separates "shutting down" from
    "leaked".
    """
    yield
    deadline = time.monotonic() + 5.0
    leaked = _serving_threads()
    while leaked and time.monotonic() < deadline:
        time.sleep(0.05)
        leaked = _serving_threads()
    assert not leaked, (
        "test leaked live serving threads (missing close()?): "
        + ", ".join(t.name for t in leaked))
